"""Online likelihood estimation + drift detection from serving traffic.

``OnlineLikelihoodEstimator`` consumes the entity ids a serving engine
returns (its top-1 per query by default — the entity the traffic was
*for*, in the paper's entity-retrieval reading) and maintains:

  * a smoothed, exponentially-decayed likelihood vector over the corpus,
    backed either by a :class:`repro.adaptive.sketch.CountMinSketch`
    (default — O(width) memory, batches with search) or by exact decayed
    counts (``width=None`` — O(N) memory, exact);
  * drift metrics against a *reference* likelihood — the vector the
    current index was (re)boosted with: total variation in [0, 1] and
    KL divergence in bits.

The maintenance scheduler polls :meth:`drift` and, past a threshold,
feeds :meth:`likelihood` into ``reboost`` and calls
:meth:`set_reference` so drift measures distance from the *deployed*
tree again.
"""
from __future__ import annotations

import threading
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.adaptive.sketch import CountMinSketch
from repro.adaptive.sketch import _query as _sketch_query
from repro.core.likelihood import decayed_empirical_likelihood

__all__ = ["OnlineLikelihoodEstimator"]


class OnlineLikelihoodEstimator:
    """Turns returned entity ids into a likelihood estimate + drift.

    ``halflife`` (observations) controls how fast old traffic fades;
    ``smoothing`` is the Laplace term shared with
    :func:`repro.core.likelihood.empirical_likelihood`.  Thread-safe:
    the engine worker calls :meth:`observe` while a maintenance thread
    calls :meth:`drift`/:meth:`likelihood`.
    """

    def __init__(
        self,
        n_entities: int,
        *,
        reference: Optional[np.ndarray] = None,
        halflife: float = 4096.0,
        smoothing: Optional[float] = None,
        width: Optional[int] = 4096,
        depth: int = 4,
        topk: int = 64,
        seed: int = 0,
    ):
        if n_entities <= 0:
            raise ValueError("n_entities must be positive")
        self.n = int(n_entities)
        self.halflife = float(halflife)
        if smoothing is None:
            # total pseudo-mass ~= 10% of the steady decayed observation
            # mass (halflife/ln2), spread over all entities.  A fixed
            # per-entity constant looks harmless but at n >> mass it
            # swamps the estimate: likelihood() goes ~uniform, reboosts
            # boost nothing, and a reference stored from it never matches
            # the raw-count drift again.
            steady = (self.halflife / np.log(2.0)
                      if np.isfinite(self.halflife) else self.n)
            smoothing = 0.1 * steady / self.n
        self.smoothing = float(smoothing)
        self._lock = threading.Lock()
        self.sketch: Optional[CountMinSketch] = None
        self._counts: Optional[np.ndarray] = None
        if width is None:
            self._counts = np.zeros(self.n, np.float64)
        else:
            self.sketch = CountMinSketch(
                width=width, depth=depth, topk=topk,
                halflife=halflife, seed=seed)
        self._all_ids = np.arange(self.n, dtype=np.int64)
        self.set_reference(reference)
        self.n_total = 0           # raw (undecayed) observation count

    # ------------------------------------------------------------------
    def set_reference(self, p: Optional[np.ndarray]) -> None:
        """Likelihood the deployed index was (re)boosted with."""
        if p is None:
            ref = np.full(self.n, 1.0 / self.n)
        else:
            ref = np.asarray(p, np.float64)
            if ref.shape[0] != self.n:
                raise ValueError(
                    f"reference has {ref.shape[0]} entries for "
                    f"{self.n} entities")
            # tiny floor only (not the full Laplace term, which would
            # visibly distort an already-normalized vector): keeps the KL
            # finite when the reference has exact zeros
            ref = np.maximum(ref, 0.0) + 1e-12
            ref = ref / ref.sum()
        with self._lock:
            self._ref = ref

    def resize(self, n_entities: int) -> None:
        """Grow to a larger corpus after ``add_entities``.

        New entities start with zero observed and (near-)zero reference
        mass, so traffic on them reads as drift — which it is.  Ids at or
        beyond the old ``n`` were dropped by :meth:`observe` until the
        resize (the maintenance scheduler resizes before every reboost).
        Shrinking is rejected: deletes keep ids stable, they don't
        compact the id space.
        """
        n_new = int(n_entities)
        if n_new < self.n:
            raise ValueError(
                f"cannot shrink estimator from {self.n} to {n_new}")
        if n_new == self.n:
            return
        with self._lock:
            extra = n_new - self.n
            if self._counts is not None:
                self._counts = np.concatenate(
                    [self._counts, np.zeros(extra)])
            ref = np.concatenate([self._ref, np.full(extra, 1e-12)])
            self._ref = ref / ref.sum()
            self.n = n_new
            self._all_ids = np.arange(self.n, dtype=np.int64)

    def observe(self, ids: np.ndarray,
                weights: Optional[np.ndarray] = None) -> int:
        """Fold a batch of returned entity ids in; returns #valid ids."""
        ids = np.asarray(ids, dtype=np.int64).ravel()
        keep = (ids >= 0) & (ids < self.n)
        ids = ids[keep]
        if weights is not None:
            weights = np.asarray(weights, np.float64).ravel()[keep]
        if ids.size == 0:
            return 0
        with self._lock:
            if self.sketch is not None:
                self.sketch.update(ids, weights)
            else:
                _, self._counts = decayed_empirical_likelihood(
                    ids, self.n, self.halflife, self.smoothing,
                    prior_counts=self._counts, return_counts=True)
            self.n_total += int(ids.size)
        return int(ids.size)

    @property
    def n_observed(self) -> float:
        """Decayed observation mass currently in the estimate."""
        if self.sketch is not None:
            return float(self.sketch.n_observed)
        return float(self._counts.sum())

    def likelihood(self) -> np.ndarray:
        """Smoothed decayed likelihood over all ``n`` entities."""
        counts = self._raw_counts()
        p = counts + self.smoothing
        return p / p.sum()

    def current_raw(self) -> np.ndarray:
        """Raw normalized decayed counts — the drift gauge's view.

        Use this (not the Laplace-smoothed :meth:`likelihood`) as the new
        reference when re-anchoring after maintenance: :meth:`drift`
        compares raw counts, and at low observation mass the smoothing
        blend would read as residual drift forever.
        """
        counts = self._raw_counts()
        s = counts.sum()
        return counts / s if s > 0 else np.full(self.n, 1.0 / self.n)

    def heavy_hitters(self) -> tuple[np.ndarray, np.ndarray]:
        """Current head of the traffic (ids, decayed count estimates)."""
        if self.sketch is not None:
            return self.sketch.heavy_hitters()
        order = np.argsort(self._counts)[::-1][:64]
        keep = self._counts[order] > 0
        return order[keep], self._counts[order][keep]

    def _counts_and_ref(self) -> tuple[np.ndarray, np.ndarray]:
        """Decayed counts + reference captured under ONE lock hold.

        The lock only covers the snapshot: the sketch's table/hash arrays
        are replaced (never mutated) by updates, so the O(n) full-corpus
        query runs outside the lock and the serving worker's observe()
        is never blocked behind it.
        """
        with self._lock:
            ref = self._ref
            ids = self._all_ids
            if self.sketch is None:
                return self._counts.copy(), ref
            table, a, b = self.sketch.table, self.sketch._a, self.sketch._b
        counts = np.asarray(_sketch_query(table, a, b, jnp.asarray(ids)),
                            dtype=np.float64)
        return counts, ref

    def _raw_counts(self) -> np.ndarray:
        return self._counts_and_ref()[0]

    def drift(self, head: int = 256) -> dict:
        """Distance of current traffic from the deployed reference.

        Computed on *raw* normalized decayed counts (not the smoothed
        likelihood — Laplace pseudo-mass would shrink every signal toward
        uniform by a mass-dependent factor) over the union of both sides'
        top-``head`` entities, with everything else lumped into one tail
        bucket: per-entity tail counts are 0/1 sampling noise, but the
        *head moving* is exactly the drift a reboost can exploit.

        ``tv``  — head-lumped total variation in raw-traffic units [0, 1];
        ``kl``  — head-lumped KL divergence in bits (floored, finite);
        ``n_observed`` — decayed observation mass behind the estimate
        (gate maintenance on it: drift of a fresh estimator is noise).
        """
        # counts and reference snapshotted under ONE lock acquisition
        # (_counts_and_ref): a concurrent resize() grows both, and mixing
        # lengths across the boundary would index out of range
        counts, ref = self._counts_and_ref()
        mass = float(counts.sum())
        if mass <= 0:
            return {"tv": 0.0, "kl": 0.0, "n_observed": 0.0}
        p = counts / mass
        k = min(head, self.n)
        hp = np.argpartition(p, -k)[-k:]
        hr = np.argpartition(ref, -k)[-k:]
        idx = np.union1d(hp, hr)
        ph, rh = p[idx], ref[idx]
        pt, rt = max(1.0 - ph.sum(), 0.0), max(1.0 - rh.sum(), 0.0)
        tv = 0.5 * float(np.abs(ph - rh).sum() + abs(pt - rt))
        eps = 1e-12
        nz = ph > eps
        kl = float((ph[nz] * np.log2(ph[nz]
                                     / np.maximum(rh[nz], eps))).sum())
        if pt > eps:
            kl += float(pt * np.log2(pt / max(rt, eps)))
        return {"tv": tv, "kl": kl, "n_observed": self.n_observed}
