"""RecSys ranking/retrieval models: DLRM, DCN-v2, DIN, SASRec.

Shared anatomy (taxonomy §RecSys): huge sparse embedding tables ->
feature-interaction op (dot / cross / target-attn / causal self-attn) ->
small MLP.  Tables: DLRM/DCN fuse all 26 Criteo tables into one array with
offsets (one gather) column-sharded over tp; DIN/SASRec tables (dims 18/50,
% 16 != 0) row-shard over tp.

``retrieval_logits`` is the paper-integration point: factorized models
(DIN/SASRec) score 1 M candidates as user·item — exactly the paper's ANN
problem; the serving layer can swap the exact dot-top-k for the two-level
index (DESIGN.md §5).  DLRM/DCN score candidates through the full joint
MLP (exact bulk scoring, shardable over candidates).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DCNConfig, DINConfig, DLRMConfig, SASRecConfig
from repro.distributed.sharding import ShardPlan
from repro.models import base
from repro.models.attention import attention
from repro.models.embedding import concat_table_offsets, take_embeddings

__all__ = ["init", "param_specs", "param_shapes", "loss_fn",
           "serve_logits", "retrieval_logits"]


def _mlp_params(mk, plan, prefix, dims):
    p = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        # shard a dim only when the mesh axis divides it AND the matrix is
        # big enough to matter: sharding DIN's 144x80 attention MLP over tp
        # made XLA all-gather the (1M, 100, 144) candidate activations
        # instead of the 46 KB weight (3.6 GB/chip — EXPERIMENTS.md §Perf)
        if a * b >= 1 << 20:
            w_spec = plan.div_p((a, b), "fsdp", "tp")
        else:
            w_spec = plan.p(None, None)
        p[f"w{i}"] = mk(f"{prefix}/w{i}", (a, b), w_spec)
        p[f"b{i}"] = mk(f"{prefix}/b{i}", (b,), plan.p(None), init="zeros")
    return p


def _mlp_apply(p, x, *, act=jax.nn.relu, final_act=None):
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


# ---------------------------------------------------------------------------
# DLRM
# ---------------------------------------------------------------------------


def _dlrm_params(cfg: DLRMConfig, mk, plan):
    _, total = concat_table_offsets(cfg.table_sizes)
    d = cfg.embed_dim
    return {
        "table": mk("table", (total, d), plan.div_p((total, d), None, "tp"),
                    init=("normal", 0.01)),
        "bot": _mlp_params(mk, plan, "bot",
                           (cfg.n_dense,) + tuple(cfg.bot_mlp)),
        "top": _mlp_params(mk, plan, "top",
                           (_dlrm_top_in(cfg),) + tuple(cfg.top_mlp)),
    }


def _dlrm_top_in(cfg: DLRMConfig):
    f = cfg.n_sparse + 1
    return f * (f - 1) // 2 + cfg.embed_dim


def _dlrm_forward(params, dense, sparse, cfg: DLRMConfig, plan, e=None):
    """dense (B, 13), sparse (B, 26) global-offset ids -> logit (B,).

    ``e`` optionally carries pre-gathered embeddings — the sparse-update
    training path differentiates w.r.t. the gathered rows instead of the
    whole table (train/sparse_embed.py).
    """
    if e is None:
        e = take_embeddings(params["table"], sparse)       # (B, 26, D)
    z0 = _mlp_apply(params["bot"], dense, act=jax.nn.relu,
                    final_act=jax.nn.relu)                 # (B, D)
    z = jnp.concatenate([z0[:, None, :], e], axis=1)       # (B, 27, D)
    inter = jnp.einsum("bfd,bgd->bfg", z, z)               # (B, 27, 27)
    f = z.shape[1]
    iu, ju = np.triu_indices(f, k=1)
    flat = inter[:, iu, ju]                                # (B, 351)
    x = jnp.concatenate([z0, flat], axis=1)
    return _mlp_apply(params["top"], x)[:, 0]


# ---------------------------------------------------------------------------
# DCN-v2
# ---------------------------------------------------------------------------


def _dcn_params(cfg: DCNConfig, mk, plan):
    _, total = concat_table_offsets(cfg.table_sizes)
    d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    p = {
        "table": mk("table", (total, cfg.embed_dim),
                    plan.div_p((total, cfg.embed_dim), None, "tp"),
                    init=("normal", 0.01)),
        "mlp": _mlp_params(mk, plan, "mlp", (d0,) + tuple(cfg.mlp) + (1,)),
    }
    for i in range(cfg.n_cross_layers):
        p[f"cross_w{i}"] = mk(f"cross_w{i}", (d0, d0),
                              plan.div_p((d0, d0), "fsdp", "tp"))
        p[f"cross_b{i}"] = mk(f"cross_b{i}", (d0,), plan.p(None),
                              init="zeros")
    return p


def _dcn_forward(params, dense, sparse, cfg: DCNConfig, plan, e=None):
    if e is None:
        e = take_embeddings(params["table"], sparse)       # (B, 26, D)
    x0 = jnp.concatenate([dense, e.reshape(e.shape[0], -1)], axis=1)
    x = x0
    for i in range(cfg.n_cross_layers):
        xw = x @ params[f"cross_w{i}"] + params[f"cross_b{i}"]
        x = x0 * xw + x                                     # DCN-v2 cross
    return _mlp_apply(params["mlp"], x)[:, 0]


# ---------------------------------------------------------------------------
# DIN
# ---------------------------------------------------------------------------


def _din_params(cfg: DINConfig, mk, plan):
    d = cfg.embed_dim
    att_in = 4 * 2 * d                                      # [et,eh,et-eh,et*eh]
    mlp_in = 2 * 2 * d                                      # [user_sum, target]
    return {
        "item_table": mk("item_table", (cfg.n_items, d),
                         plan.div_p((cfg.n_items, d), "tp", None),
                         init=("normal", 0.01)),
        "cate_table": mk("cate_table", (cfg.n_cates, d), plan.p(None, None),
                         init=("normal", 0.01)),
        "att": _mlp_params(mk, plan, "att",
                           (att_in,) + tuple(cfg.attn_mlp) + (1,)),
        "mlp": _mlp_params(mk, plan, "mlp",
                           (mlp_in,) + tuple(cfg.mlp) + (1,)),
    }


def _din_user_embed(params, hist_items, hist_cates, target_e):
    """Target attention over history -> (B, 2D) user interest vector."""
    eh = jnp.concatenate(
        [take_embeddings(params["item_table"], hist_items),
         take_embeddings(params["cate_table"], hist_cates)], axis=-1,
    )                                                       # (B, L, 2D)
    et = target_e[:, None, :]                               # (B, 1, 2D)
    etb = jnp.broadcast_to(et, eh.shape)
    att_in = jnp.concatenate([etb, eh, etb - eh, etb * eh], axis=-1)
    scores = _mlp_apply(params["att"], att_in,
                        act=jax.nn.sigmoid)[..., 0]         # (B, L)
    scores = jnp.where(hist_items >= 0, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return (w[..., None] * eh).sum(axis=1)                  # (B, 2D)


def _din_forward(params, batch, cfg: DINConfig, plan):
    et = jnp.concatenate(
        [take_embeddings(params["item_table"], batch["target_item"]),
         take_embeddings(params["cate_table"], batch["target_cate"])],
        axis=-1,
    )                                                       # (B, 2D)
    user = _din_user_embed(params, batch["hist_items"], batch["hist_cates"],
                           et)
    x = jnp.concatenate([user, et], axis=-1)
    return _mlp_apply(params["mlp"], x)[:, 0]


# ---------------------------------------------------------------------------
# SASRec
# ---------------------------------------------------------------------------


def _sasrec_params(cfg: SASRecConfig, mk, plan):
    d = cfg.embed_dim
    L = (cfg.n_blocks,)
    sp = lambda *dims: plan.p(None, *dims)
    # +1 pad row for -1 ids; round rows up so tp row-sharding divides
    tp_n = max(plan.size_of("tp"), 1)
    rows = -(-(cfg.n_items + 1) // tp_n) * tp_n
    return {
        "item_table": mk("item_table", (rows, d),
                         plan.div_p((rows, d), "tp", None),
                         init=("normal", 0.01)),
        "pos_table": mk("pos_table", (cfg.seq_len, d), plan.p(None, None),
                        init=("normal", 0.01)),
        "blocks": {
            "ln1": mk("blocks/ln1", L + (d,), sp(None), init="ones"),
            "ln2": mk("blocks/ln2", L + (d,), sp(None), init="ones"),
            "w_q": mk("blocks/w_q", L + (d, cfg.n_heads, d // cfg.n_heads),
                      sp(None, None, None)),
            "w_k": mk("blocks/w_k", L + (d, cfg.n_heads, d // cfg.n_heads),
                      sp(None, None, None)),
            "w_v": mk("blocks/w_v", L + (d, cfg.n_heads, d // cfg.n_heads),
                      sp(None, None, None)),
            "w_o": mk("blocks/w_o", L + (cfg.n_heads, d // cfg.n_heads, d),
                      sp(None, None, None)),
            "f_w1": mk("blocks/f_w1", L + (d, d), sp(None, None)),
            "f_b1": mk("blocks/f_b1", L + (d,), sp(None), init="zeros"),
            "f_w2": mk("blocks/f_w2", L + (d, d), sp(None, None)),
            "f_b2": mk("blocks/f_b2", L + (d,), sp(None), init="zeros"),
        },
        "final_ln": mk("final_ln", (d,), plan.p(None), init="ones"),
    }


def _sasrec_hidden(params, seq, cfg: SASRecConfig, plan):
    """seq (B, L) item ids (-1 pad) -> hidden (B, L, D)."""
    from repro.models.layers import rms_norm

    x = take_embeddings(params["item_table"], seq)
    x = x + params["pos_table"][None, : seq.shape[1]]
    x = jnp.where((seq >= 0)[..., None], x, 0.0)
    for i in range(cfg.n_blocks):
        bp = jax.tree.map(lambda a: a[i], params["blocks"])
        h = rms_norm(x, bp["ln1"])
        q = jnp.einsum("bsd,dhk->bshk", h, bp["w_q"])
        k = jnp.einsum("bsd,dhk->bshk", h, bp["w_k"])
        v = jnp.einsum("bsd,dhk->bshk", h, bp["w_v"])
        o = attention(q, k, v, causal=True,
                      kv_mask=(seq >= 0).astype(jnp.int32))
        x = x + jnp.einsum("bshk,hkd->bsd", o, bp["w_o"])
        h = rms_norm(x, bp["ln2"])
        f = jax.nn.relu(h @ bp["f_w1"] + bp["f_b1"])
        x = x + f @ bp["f_w2"] + bp["f_b2"]
    from repro.models.layers import rms_norm as _rn

    return _rn(x, params["final_ln"])


def _sasrec_loss(params, batch, cfg: SASRecConfig, plan):
    """BCE over (positive next item, sampled negative) per position."""
    h = _sasrec_hidden(params, batch["seq"], cfg, plan)     # (B, L, D)
    pos_e = take_embeddings(params["item_table"], batch["pos"])
    neg_e = take_embeddings(params["item_table"], batch["neg"])
    pos_s = (h * pos_e).sum(-1)
    neg_s = (h * neg_e).sum(-1)
    mask = (batch["pos"] >= 0).astype(jnp.float32)
    loss = -(jax.nn.log_sigmoid(pos_s) + jax.nn.log_sigmoid(-neg_s))
    loss = (loss * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# unified API
# ---------------------------------------------------------------------------

_PARAM_FNS = {
    DLRMConfig: _dlrm_params,
    DCNConfig: _dcn_params,
    DINConfig: _din_params,
    SASRecConfig: _sasrec_params,
}


def _param_fn(cfg, mk, plan):
    return _PARAM_FNS[type(cfg)](cfg, mk, plan)


def init(cfg, key, plan: ShardPlan = ShardPlan()):
    return base.build_params(partial(_param_fn, plan=plan), cfg, key)


def param_specs(cfg, plan: ShardPlan):
    return base.build_specs(partial(_param_fn, plan=plan), cfg)


def param_shapes(cfg, plan: ShardPlan):
    return base.build_shapes(partial(_param_fn, plan=plan), cfg)


def serve_logits(params, batch, cfg, plan: ShardPlan = ShardPlan()):
    """Pointwise CTR logits for a request batch."""
    if isinstance(cfg, DLRMConfig):
        return _dlrm_forward(params, batch["dense"], batch["sparse"], cfg,
                             plan)
    if isinstance(cfg, DCNConfig):
        return _dcn_forward(params, batch["dense"], batch["sparse"], cfg,
                            plan)
    if isinstance(cfg, DINConfig):
        return _din_forward(params, batch, cfg, plan)
    if isinstance(cfg, SASRecConfig):
        h = _sasrec_hidden(params, batch["seq"], cfg, plan)
        e = take_embeddings(params["item_table"], batch["target_item"])
        return (h[:, -1] * e).sum(-1)
    raise TypeError(type(cfg))


def loss_fn(params, batch, cfg, plan: ShardPlan = ShardPlan()):
    """BCE with logits against batch['label'] (SASRec: in-sequence BCE)."""
    if isinstance(cfg, SASRecConfig):
        return _sasrec_loss(params, batch, cfg, plan)
    logits = serve_logits(params, batch, cfg, plan)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * y
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    acc = jnp.mean((logits > 0) == (y > 0.5))
    return loss, {"loss": loss, "accuracy": acc}


def ctr_forward_gathered(rest, e, batch, cfg, plan: ShardPlan = ShardPlan()):
    """DLRM/DCN forward with pre-gathered embeddings (sparse-update path).

    ``rest`` is the param tree minus the table (table may be absent)."""
    fwd = _dlrm_forward if isinstance(cfg, DLRMConfig) else _dcn_forward
    return fwd(rest, batch["dense"], batch["sparse"], cfg, plan, e=e)


def retrieval_logits(params, batch, cfg, plan: ShardPlan = ShardPlan(),
                     k: int = 100):
    """Score 1 user against n_candidates items; return (scores, ids) top-k.

    Factorized models (DIN/SASRec): user vector . candidate embeddings — the
    paper's exact ANN problem (swap in the two-level index at serve time).
    Joint models (DLRM/DCN): full forward over candidate-expanded rows,
    sharded over the mesh.
    """
    cand = batch["candidates"]                              # (C,) item ids
    if isinstance(cfg, SASRecConfig):
        h = _sasrec_hidden(params, batch["seq"], cfg, plan)[:, -1]   # (1, D)
        e = take_embeddings(params["item_table"], cand)
        e = plan.constrain(e, ("dp", "tp"), None)
        scores = (h @ e.T)[0]
    elif isinstance(cfg, DINConfig):
        et = jnp.concatenate(
            [take_embeddings(params["item_table"], cand),
             take_embeddings(params["cate_table"], batch["cand_cates"])],
            axis=-1,
        )                                                   # (C, 2D)
        et = plan.constrain(et, ("dp", "tp"), None)
        # user tower depends on the target (target attention): recompute the
        # attention per candidate but share the history embeddings.
        user = jax.vmap(
            lambda e_one: _din_user_embed(
                params, batch["hist_items"], batch["hist_cates"],
                e_one[None],
            )[0]
        )(et)                                               # (C, 2D)
        x = jnp.concatenate([user, et], axis=-1)
        scores = _mlp_apply(params["mlp"], x)[:, 0]
    elif isinstance(cfg, (DLRMConfig, DCNConfig)):
        c = cand.shape[0]
        dense = jnp.broadcast_to(batch["dense"], (c, batch["dense"].shape[-1]))
        sparse = jnp.broadcast_to(batch["sparse"],
                                  (c, batch["sparse"].shape[-1]))
        # candidate id replaces the item feature column (feature 0)
        sparse = sparse.at[:, 0].set(cand)
        sparse = plan.constrain(sparse, ("dp", "tp"), None)
        fwd = _dlrm_forward if isinstance(cfg, DLRMConfig) else _dcn_forward
        scores = fwd(params, dense, sparse, cfg, plan)
    else:
        raise TypeError(type(cfg))
    top, ids = jax.lax.top_k(scores.astype(jnp.float32), k)
    return top, cand[ids]    # highest-scoring candidates, descending
