"""Decoder-only LM covering all five assigned transformer archs.

  qwen3-14b / qwen3-0.6b : GQA + qk-norm + SwiGLU
  granite-34b            : MQA (kv=1) + GELU MLP (2-matrix, code model)
  deepseek-v3-671b       : MLA + MoE(256e top-8, 1 shared) + MTP
  kimi-k2-1t-a32b        : MLA + MoE(384e top-8, 1 shared)

Functional: ``init(cfg, key)`` / ``param_specs(cfg, plan)`` build the
parameter pytree and its PartitionSpec twin; ``loss_fn`` / ``prefill`` /
``decode_step`` are pure.  Repeated layers are scanned over stacked
params (HLO size O(1) in depth — required for 512-way SPMD compiles), with
``jax.checkpoint`` around the layer body for remat.

Distribution (DESIGN.md §4): params 2-D sharded (fsdp x tp); residual
stream sharded (dp, tp-on-sequence, -) when ``attn_shard == "seq"`` (qwen3,
40 heads % 16 != 0) else (dp, -, -) with heads sharded inside attention;
MLA decode uses the absorbed-latent path so the cache is (kv_lora + rope)
per token; KV caches shard batch over dp and sequence over tp.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.distributed.sharding import ShardPlan
from repro.models import base
from repro.models.attention import attention, decode_attention
from repro.models.layers import (
    gelu_mlp,
    rms_norm,
    rope,
    rope_tables,
    softmax_xent,
    swiglu,
)
from repro.models.moe import moe_ffn, moe_params

__all__ = ["init", "param_specs", "param_shapes", "loss_fn", "prefill",
           "decode_step", "cache_shapes", "cache_specs", "LMConfig",
           "set_precision"]

# precision policy: compute dtype for layer math, storage dtype for the KV
# cache. bf16/bf16 in production; tests flip to f32 to separate numerics
# from logic (test_models).
COMPUTE_DTYPE = jnp.bfloat16
CACHE_DTYPE = jnp.bfloat16


def set_precision(compute=jnp.bfloat16, cache=jnp.bfloat16):
    global COMPUTE_DTYPE, CACHE_DTYPE
    COMPUTE_DTYPE = compute
    CACHE_DTYPE = cache

MTP_WEIGHT = 0.3


# ---------------------------------------------------------------------------
# parameter description
# ---------------------------------------------------------------------------


def _attn_params(cfg: LMConfig, mk, plan: ShardPlan, prefix: str, L: int):
    d = cfg.d_model
    pp = lambda *dims: plan.p(None, *dims)
    tp_n = max(plan.axis_size("tp"), 1)
    kv_tp = "tp" if cfg.n_kv_heads % tp_n == 0 else None
    q_tp = "tp" if cfg.n_heads % tp_n == 0 else None
    p = {
        "ln1": mk(f"{prefix}/ln1", (L, d), pp(None), init="ones"),
        "ln2": mk(f"{prefix}/ln2", (L, d), pp(None), init="ones"),
    }
    if cfg.attn_kind == "mla":
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        p.update({
            "w_dq": mk(f"{prefix}/w_dq", (L, d, m.q_lora_rank),
                       pp("fsdp", None)),
            "q_ln": mk(f"{prefix}/q_ln", (L, m.q_lora_rank), pp(None),
                       init="ones"),
            "w_uq": mk(f"{prefix}/w_uq", (L, m.q_lora_rank, cfg.n_heads, qk),
                       pp(None, q_tp, None)),
            "w_dkv": mk(f"{prefix}/w_dkv", (L, d, m.kv_lora_rank),
                        pp("fsdp", None)),
            "kv_ln": mk(f"{prefix}/kv_ln", (L, m.kv_lora_rank), pp(None),
                        init="ones"),
            "w_kr": mk(f"{prefix}/w_kr", (L, d, m.qk_rope_head_dim),
                       pp("fsdp", None)),
            "w_uk": mk(f"{prefix}/w_uk",
                       (L, m.kv_lora_rank, cfg.n_heads, m.qk_nope_head_dim),
                       pp(None, q_tp, None)),
            "w_uv": mk(f"{prefix}/w_uv",
                       (L, m.kv_lora_rank, cfg.n_heads, m.v_head_dim),
                       pp(None, q_tp, None)),
            "w_o": mk(f"{prefix}/w_o",
                      (L, cfg.n_heads, m.v_head_dim, d),
                      pp(q_tp, None, "fsdp")),
        })
    else:
        dh = cfg.d_head
        p.update({
            "w_q": mk(f"{prefix}/w_q", (L, d, cfg.n_heads, dh),
                      pp("fsdp", q_tp, None)),
            "w_k": mk(f"{prefix}/w_k", (L, d, cfg.n_kv_heads, dh),
                      pp("fsdp", kv_tp, None)),
            "w_v": mk(f"{prefix}/w_v", (L, d, cfg.n_kv_heads, dh),
                      pp("fsdp", kv_tp, None)),
            "w_o": mk(f"{prefix}/w_o", (L, cfg.n_heads, dh, d),
                      pp(q_tp, None, "fsdp")),
        })
        if cfg.qk_norm:
            p["q_norm"] = mk(f"{prefix}/q_norm", (L, dh), pp(None),
                             init="ones")
            p["k_norm"] = mk(f"{prefix}/k_norm", (L, dh), pp(None),
                             init="ones")
    return p


def _mlp_params(cfg: LMConfig, mk, plan, prefix: str, L: int, d_ff: int):
    d = cfg.d_model
    pp = lambda *dims: plan.p(None, *dims)
    if cfg.mlp_kind == "gelu":
        return {
            "w_up": mk(f"{prefix}/w_up", (L, d, d_ff), pp("fsdp", "tp")),
            "w_down": mk(f"{prefix}/w_down", (L, d_ff, d),
                         pp("tp", "fsdp")),
        }
    return {
        "w_gate": mk(f"{prefix}/w_gate", (L, d, d_ff), pp("fsdp", "tp")),
        "w_up": mk(f"{prefix}/w_up", (L, d, d_ff), pp("fsdp", "tp")),
        "w_down": mk(f"{prefix}/w_down", (L, d_ff, d), pp("tp", "fsdp")),
    }


def _param_fn(cfg: LMConfig, mk, plan: ShardPlan):
    d, v = cfg.d_model, cfg.vocab
    n_dense = cfg.n_dense_layers if cfg.moe else cfg.n_layers
    n_moe = cfg.n_moe_layers
    params = {
        "embed": mk("embed", (v, d), plan.p("tp", "fsdp"),
                    init=("normal", 0.02)),
        "final_norm": mk("final_norm", (d,), plan.p(None), init="ones"),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = mk("unembed", (d, v), plan.p("fsdp", "tp"))
    if n_dense:
        params["dense_layers"] = {
            **_attn_params(cfg, mk, plan, "dense/attn", n_dense),
            **_mlp_params(cfg, mk, plan, "dense/mlp", n_dense, cfg.d_ff),
        }
    if n_moe:
        params["moe_layers"] = {
            **_attn_params(cfg, mk, plan, "moe/attn", n_moe),
            **moe_params(cfg, mk, plan, "moe/mlp", n_moe),
        }
    if cfg.mtp:
        params["mtp"] = {
            "proj": mk("mtp/proj", (2 * d, d), plan.p("fsdp", None)),
            "norm_h": mk("mtp/norm_h", (d,), plan.p(None), init="ones"),
            "norm_e": mk("mtp/norm_e", (d,), plan.p(None), init="ones"),
            **_attn_params(cfg, mk, plan, "mtp/attn", 1),
            **_mlp_params(cfg, mk, plan, "mtp/mlp", 1,
                          cfg.moe.d_ff * 8 if cfg.moe else cfg.d_ff),
        }
    return params


def init(cfg: LMConfig, key, plan: ShardPlan = ShardPlan()):
    dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    return base.build_params(partial(_param_fn, plan=plan), cfg, key,
                             dtype=dtype)


def param_specs(cfg: LMConfig, plan: ShardPlan):
    return base.build_specs(partial(_param_fn, plan=plan), cfg)


def param_shapes(cfg: LMConfig, plan: ShardPlan):
    dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    return base.build_shapes(partial(_param_fn, plan=plan), cfg, dtype=dtype)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _res_constrain(x, cfg, plan):
    if cfg.attn_shard == "seq":
        return plan.constrain(x, "dp", "tp", None)
    return plan.constrain(x, "dp", None, None)


def _head_roles(cfg, plan):
    tp_n = max(plan.axis_size("tp"), 1)
    q_tp = "tp" if cfg.n_heads % tp_n == 0 else None
    kv_tp = "tp" if cfg.n_kv_heads % tp_n == 0 else None
    return q_tp, kv_tp


def _gather_fsdp(plan, w, *dims):
    """FSDP weight gathering: re-constrain a param so its fsdp axes are
    replicated (tp sharding kept) right before use.  Without this XLA's
    SPMD dot handler sometimes prefers partial contraction + a (batch,
    seq, out)-sized all-reduce — catastrophically larger than gathering
    the weight (observed 8 GiB/step on qwen3-0.6b; EXPERIMENTS.md §Perf).
    """
    return plan.constrain(w, *dims)


def _attention_block(p, h, cfg: LMConfig, plan, cos, sin):
    """h: post-ln1 hidden (B, S, D) -> attn output (B, S, D)."""
    chunk = cfg.attn_chunk or None
    q_tp, kv_tp = _head_roles(cfg, plan)
    if cfg.attn_kind == "mla":
        m = cfg.mla
        w_dq = _gather_fsdp(plan, p["w_dq"], None, None)
        w_dkv = _gather_fsdp(plan, p["w_dkv"], None, None)
        w_kr = _gather_fsdp(plan, p["w_kr"], None, None)
        w_o = _gather_fsdp(plan, p["w_o"], q_tp, None, None)
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", h, w_dq), p["q_ln"])
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
        q_nope = q[..., : m.qk_nope_head_dim]
        q_rope = rope(q[..., m.qk_nope_head_dim:], cos, sin)
        ckv = rms_norm(jnp.einsum("bsd,dr->bsr", h, w_dkv), p["kv_ln"])
        k_rope = rope(
            jnp.einsum("bsd,dk->bsk", h, w_kr)[:, :, None, :],
            cos, sin,
        )                                                   # (B,S,1,rope)
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"])
        v = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"])
        k = jnp.concatenate(
            [k_nope,
             jnp.broadcast_to(k_rope, k_nope.shape[:3] + k_rope.shape[-1:])],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        # Megatron-SP: attention itself is heads-sharded even when the
        # residual is sequence-sharded — MLA expands K per head, so an
        # unsharded-heads K is (b, S, 128, 192) per chip (EXPERIMENTS §Perf)
        q = plan.constrain(q, "dp", None, q_tp, None)
        k = plan.constrain(k, "dp", None, q_tp, None)
        v = plan.constrain(v, "dp", None, q_tp, None)
        scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
        o = attention(q, k, v, causal=True, chunk=chunk, scale=scale)
        return jnp.einsum("bshk,hkd->bsd", o, w_o)
    w_q = _gather_fsdp(plan, p["w_q"], None, q_tp, None)
    w_k = _gather_fsdp(plan, p["w_k"], None, kv_tp, None)
    w_v = _gather_fsdp(plan, p["w_v"], None, kv_tp, None)
    w_o = _gather_fsdp(plan, p["w_o"], q_tp, None, None)
    q = jnp.einsum("bsd,dhk->bshk", h, w_q)
    k = jnp.einsum("bsd,dhk->bshk", h, w_k)
    v = jnp.einsum("bsd,dhk->bshk", h, w_v)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = rope(q, cos, sin)
    k = rope(k, cos, sin)
    q = plan.constrain(q, "dp", None, q_tp, None)
    k = plan.constrain(k, "dp", None, kv_tp, None)
    v = plan.constrain(v, "dp", None, kv_tp, None)
    o = attention(q, k, v, causal=True, chunk=chunk)
    return jnp.einsum("bshk,hkd->bsd", o, w_o)


def _mlp_block(p, h, cfg: LMConfig, plan, *, moe: bool):
    if moe:
        return moe_ffn(p, h, cfg, plan)
    if cfg.mlp_kind == "gelu":
        return gelu_mlp(h, _gather_fsdp(plan, p["w_up"], None, "tp"),
                        _gather_fsdp(plan, p["w_down"], "tp", None))
    return swiglu(h, _gather_fsdp(plan, p["w_gate"], None, "tp"),
                  _gather_fsdp(plan, p["w_up"], None, "tp"),
                  _gather_fsdp(plan, p["w_down"], "tp", None))


def _layer(p, x, cfg, plan, cos, sin, *, moe: bool):
    cdt = COMPUTE_DTYPE
    h = rms_norm(x, p["ln1"]).astype(cdt)
    x = x + _attention_block(base.cast_tree(p, cdt), h, cfg, plan,
                             cos, sin).astype(x.dtype)
    x = _res_constrain(x, cfg, plan)
    h = rms_norm(x, p["ln2"]).astype(cdt)
    x = x + _mlp_block(base.cast_tree(p, cdt), h, cfg, plan,
                       moe=moe).astype(x.dtype)
    return _res_constrain(x, cfg, plan)


def _scan_layers(stack, x, cfg, plan, cos, sin, *, moe: bool):
    layer = partial(_layer, cfg=cfg, plan=plan, cos=cos, sin=sin, moe=moe)
    if cfg.remat:
        layer = jax.checkpoint(layer)
    if not cfg.scan_layers:
        L = jax.tree.leaves(stack)[0].shape[0]
        for i in range(L):
            x = layer(jax.tree.map(lambda a: a[i], stack), x)
        return x

    def body(carry, lp):
        return layer(lp, carry), None

    x, _ = jax.lax.scan(body, x, stack)
    return x


def _res_dtype(cfg: LMConfig):
    return jnp.bfloat16 if cfg.residual_dtype == "bfloat16" else jnp.float32


def _backbone(params, tokens, cfg: LMConfig, plan: ShardPlan):
    """tokens (B, S) -> final-norm hidden (B, S, D)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = _res_constrain(x.astype(_res_dtype(cfg)), cfg, plan)
    rope_dim = (cfg.mla.qk_rope_head_dim if cfg.attn_kind == "mla"
                else cfg.d_head)
    cos, sin = rope_tables(jnp.arange(s), rope_dim, cfg.rope_theta)
    if "dense_layers" in params:
        x = _scan_layers(params["dense_layers"], x, cfg, plan, cos, sin,
                         moe=False)
    if "moe_layers" in params:
        x = _scan_layers(params["moe_layers"], x, cfg, plan, cos, sin,
                         moe=True)
    return rms_norm(x, params["final_norm"])


def _logits(params, h, cfg, plan):
    if cfg.tie_embeddings:
        w = plan.constrain(params["embed"], "tp", None).T
    else:
        w = _gather_fsdp(plan, params["unembed"], None, "tp")
    logits = jnp.einsum("bsd,dv->bsv", h.astype(COMPUTE_DTYPE),
                        w.astype(COMPUTE_DTYPE))
    return plan.constrain(logits, "dp", None, "tp")


def loss_fn(params, batch, cfg: LMConfig, plan: ShardPlan = ShardPlan()):
    """batch: {tokens (B,S), labels (B,S), mask optional} -> (loss, aux)."""
    tokens, labels = batch["tokens"], batch["labels"]
    mask = batch.get("mask")
    h = _backbone(params, tokens, cfg, plan)
    logits = _logits(params, h, cfg, plan)
    loss, aux = softmax_xent(logits, labels, z_loss=1e-4, mask=mask)
    if cfg.mtp and "mtp" in params:
        mtp_loss = _mtp_loss(params, h, tokens, labels, cfg, plan, mask)
        aux["mtp_nll"] = mtp_loss
        loss = loss + MTP_WEIGHT * mtp_loss
    aux["loss"] = loss
    return loss, aux


def _mtp_loss(params, h, tokens, labels, cfg, plan, mask):
    """DeepSeek-V3 MTP (depth 1): predict token t+2 from h_t ++ emb_{t+1}."""
    p = params["mtp"]
    b, s = tokens.shape
    # shift: condition on next token's embedding
    nxt = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
    e = jnp.take(params["embed"], nxt, axis=0).astype(_res_dtype(cfg))
    hin = jnp.concatenate(
        [rms_norm(h, p["norm_h"]), rms_norm(e, p["norm_e"])], axis=-1
    )
    x = jnp.einsum("bse,ed->bsd", hin,
                   _gather_fsdp(plan, p["proj"], None, None))
    x = _res_constrain(x, cfg, plan)
    rope_dim = (cfg.mla.qk_rope_head_dim if cfg.attn_kind == "mla"
                else cfg.d_head)
    cos, sin = rope_tables(jnp.arange(s), rope_dim, cfg.rope_theta)
    lp = jax.tree.map(
        lambda a: a[0],
        {k: v for k, v in p.items()
         if k not in ("proj", "norm_h", "norm_e")},
    )
    mtp_layer = partial(_layer, cfg=cfg, plan=plan, cos=cos, sin=sin,
                        moe=False)
    if cfg.remat:
        mtp_layer = jax.checkpoint(mtp_layer)   # same policy as the stack
    x = mtp_layer(lp, x)
    logits = _logits(params, rms_norm(x, params["final_norm"]), cfg, plan)
    # labels for t+2: shift labels left by one
    l2 = jnp.concatenate([labels[:, 1:], labels[:, -1:]], axis=1)
    m2 = jnp.ones_like(l2, jnp.float32).at[:, -2:].set(0.0)
    if mask is not None:
        m2 = m2 * mask
    loss, _ = softmax_xent(logits, l2, mask=m2)
    return loss


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def cache_shapes(cfg: LMConfig, batch: int, max_len: int):
    """ShapeDtypeStruct pytree for the decode cache."""
    L = cfg.n_layers
    if cfg.attn_kind == "mla":
        m = cfg.mla
        return {
            "ckv": jax.ShapeDtypeStruct(
                (L, batch, max_len, m.kv_lora_rank), CACHE_DTYPE),
            "krope": jax.ShapeDtypeStruct(
                (L, batch, max_len, m.qk_rope_head_dim), CACHE_DTYPE),
            "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
        }
    return {
        "k": jax.ShapeDtypeStruct(
            (L, batch, max_len, cfg.n_kv_heads, cfg.d_head), CACHE_DTYPE),
        "v": jax.ShapeDtypeStruct(
            (L, batch, max_len, cfg.n_kv_heads, cfg.d_head), CACHE_DTYPE),
        "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def cache_specs(cfg: LMConfig, plan: ShardPlan):
    """Cache sharding: batch over dp, sequence over tp (DESIGN.md §4)."""
    if cfg.attn_kind == "mla":
        return {
            "ckv": plan.p(None, "dp", "tp", None),
            "krope": plan.p(None, "dp", "tp", None),
            "lengths": plan.p("dp"),
        }
    return {
        "k": plan.p(None, "dp", "tp", None, None),
        "v": plan.p(None, "dp", "tp", None, None),
        "lengths": plan.p("dp"),
    }


def _stacked_layer_params(params, cfg):
    """Recombine dense+moe stacks into per-layer iteration order."""
    stacks = []
    if "dense_layers" in params:
        stacks.append((params["dense_layers"], False,
                       jax.tree.leaves(params["dense_layers"])[0].shape[0]))
    if "moe_layers" in params:
        stacks.append((params["moe_layers"], True,
                       jax.tree.leaves(params["moe_layers"])[0].shape[0]))
    return stacks


def prefill(params, tokens, cfg: LMConfig, plan: ShardPlan = ShardPlan(),
            max_len: Optional[int] = None):
    """Full-sequence forward building the decode cache.

    Returns (last_logits (B, V), cache).  The cache sequence axis is padded
    to ``max_len`` (defaults to S).
    """
    b, s = tokens.shape
    max_len = max_len or s
    x = jnp.take(params["embed"], tokens, axis=0) \
        .astype(_res_dtype(cfg))
    x = _res_constrain(x, cfg, plan)
    rope_dim = (cfg.mla.qk_rope_head_dim if cfg.attn_kind == "mla"
                else cfg.d_head)
    cos, sin = rope_tables(jnp.arange(s), rope_dim, cfg.rope_theta)
    caches = []

    for stack, moe, L in _stacked_layer_params(params, cfg):

        def one(carry, lp, moe=moe):
            x = carry
            cdt = COMPUTE_DTYPE
            h = rms_norm(x, lp["ln1"]).astype(cdt)
            lpc = base.cast_tree(lp, cdt)
            _, kv_tp = _head_roles(cfg, plan)
            if cfg.attn_kind == "mla":
                ckv = rms_norm(
                    jnp.einsum("bsd,dr->bsr", h,
                               _gather_fsdp(plan, lpc["w_dkv"], None, None)),
                    lp["kv_ln"],
                )
                krope = rope(
                    jnp.einsum("bsd,dk->bsk", h,
                               _gather_fsdp(plan, lpc["w_kr"], None, None)
                               )[:, :, None],
                    cos, sin)[:, :, 0]
                kv_entry = (ckv.astype(CACHE_DTYPE),
                            krope.astype(CACHE_DTYPE))
            x_new = _layer(lp, x, cfg, plan, cos, sin, moe=moe)
            if cfg.attn_kind != "mla":
                w_k = _gather_fsdp(plan, lpc["w_k"], None, kv_tp, None)
                w_v = _gather_fsdp(plan, lpc["w_v"], None, kv_tp, None)
                k = jnp.einsum("bsd,dhk->bshk", h, w_k)
                v = jnp.einsum("bsd,dhk->bshk", h, w_v)
                if cfg.qk_norm:
                    k = rms_norm(k, lp["k_norm"])
                k = rope(k, cos, sin)
                kv_entry = (k.astype(CACHE_DTYPE), v.astype(CACHE_DTYPE))
            return x_new, kv_entry

        x, kv = jax.lax.scan(one, x, stack)
        caches.append(kv)

    h = rms_norm(x, params["final_norm"])
    last = h[:, -1:, :]
    logits = _logits(params, last, cfg, plan)[:, 0]
    a = jnp.concatenate([c[0] for c in caches], axis=0)
    bcat = jnp.concatenate([c[1] for c in caches], axis=0)
    pad = ((0, 0), (0, 0), (0, max_len - s)) + ((0, 0),) * (a.ndim - 3)
    a = jnp.pad(a, pad)
    bcat = jnp.pad(bcat, pad[: bcat.ndim])
    lengths = jnp.full((b,), s, jnp.int32)
    if cfg.attn_kind == "mla":
        cache = {"ckv": a, "krope": bcat, "lengths": lengths}
    else:
        cache = {"k": a, "v": bcat, "lengths": lengths}
    return logits, cache


def decode_step(params, cache, tokens, cfg: LMConfig,
                plan: ShardPlan = ShardPlan()):
    """One decode step: tokens (B, 1) + cache -> (logits (B, V), cache').

    GQA: standard cached attention.  MLA: absorbed-latent attention — query
    is projected into the kv-latent space (q @ W_uk) so the cache holds only
    (kv_lora + rope) per token and W_uv is applied to the attended latent
    (DeepSeek-V2 inference optimization).
    """
    b = tokens.shape[0]
    lengths = cache["lengths"]
    x = jnp.take(params["embed"], tokens, axis=0) \
        .astype(_res_dtype(cfg))
    # (B, 1, D)
    pos = lengths                                    # (B,) current position
    rope_dim = (cfg.mla.qk_rope_head_dim if cfg.attn_kind == "mla"
                else cfg.d_head)
    cos, sin = rope_tables(pos[:, None], rope_dim, cfg.rope_theta)
    # cos/sin: (B, 1, rope/2) — broadcast over heads inside `rope`

    layer_idx = 0
    new_caches = {k: cache[k] for k in cache}
    for stack, moe, L in _stacked_layer_params(params, cfg):

        def one(carry, xs, moe=moe):
            x, = carry
            lp, sl = xs
            x, updates = _decode_layer(lp, sl, x, cfg, plan, cos, sin,
                                       lengths, moe)
            return (x,), updates

        slices = {k: jax.lax.dynamic_slice_in_dim(cache[k], layer_idx, L, 0)
                  for k in cache if k != "lengths"}
        (x,), updates = jax.lax.scan(one, (x,), (stack, slices))
        for k in updates:
            new_caches[k] = jax.lax.dynamic_update_slice_in_dim(
                new_caches[k], updates[k], layer_idx, 0
            )
        layer_idx += L

    h = rms_norm(x, params["final_norm"])
    logits = _logits(params, h, cfg, plan)[:, 0]
    new_caches["lengths"] = lengths + 1
    return logits, new_caches


def _decode_layer(lp, sl, x, cfg, plan, cos, sin, lengths, moe):
    cdt = COMPUTE_DTYPE
    b = x.shape[0]
    h = rms_norm(x, lp["ln1"]).astype(cdt)
    lpc = base.cast_tree(lp, cdt)
    q_tp, kv_tp = _head_roles(cfg, plan)
    if cfg.attn_kind == "mla":
        m = cfg.mla
        w_dq = _gather_fsdp(plan, lpc["w_dq"], None, None)
        w_dkv = _gather_fsdp(plan, lpc["w_dkv"], None, None)
        w_kr = _gather_fsdp(plan, lpc["w_kr"], None, None)
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", h, w_dq), lp["q_ln"])
        q = jnp.einsum("bsr,rhk->bshk", cq, lpc["w_uq"])
        q_nope = q[..., : m.qk_nope_head_dim]
        q_rope = rope(q[..., m.qk_nope_head_dim:], cos, sin)
        ckv_new = rms_norm(
            jnp.einsum("bsd,dr->bsr", h, w_dkv), lp["kv_ln"]
        ).astype(CACHE_DTYPE)                        # (B,1,r)
        krope_new = rope(
            jnp.einsum("bsd,dk->bsk", h, w_kr)[:, :, None], cos, sin
        )[:, :, 0].astype(CACHE_DTYPE)               # (B,1,rope)
        # one-hot masked write: a scatter into the sequence-sharded cache
        # makes SPMD gather the whole cache (≈0.9 TB/step on deepseek
        # decode — EXPERIMENTS.md §Perf); the select is fully local.
        pos = (jnp.arange(sl["ckv"].shape[1])[None, :]
               == lengths[:, None])                  # (B, S)
        ckv = jnp.where(pos[..., None], ckv_new, sl["ckv"])
        krope = jnp.where(pos[..., None], krope_new, sl["krope"])
        # absorbed: q_lat = q_nope @ W_uk  -> score in latent space
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, lpc["w_uk"])
        s1 = jnp.einsum("bshr,btr->bhst", q_lat, ckv)
        s2 = jnp.einsum("bshk,btk->bhst", q_rope, krope)
        scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
        sc = (s1 + s2).astype(jnp.float32) * scale
        valid = (jnp.arange(sl["ckv"].shape[1])[None, :]
                 <= lengths[:, None])
        sc = jnp.where(valid[:, None, None, :], sc, -1e30)
        pr = jax.nn.softmax(sc, axis=-1).astype(cdt)
        ctx_lat = jnp.einsum("bhst,btr->bshr", pr, ckv)
        o = jnp.einsum("bshr,rhk->bshk", ctx_lat, lpc["w_uv"])
        attn_out = jnp.einsum(
            "bshk,hkd->bsd", o,
            _gather_fsdp(plan, lpc["w_o"], q_tp, None, None))
        updates = {"ckv": ckv, "krope": krope}    # scan stacks the L axis
    else:
        q = jnp.einsum("bsd,dhk->bshk", h,
                       _gather_fsdp(plan, lpc["w_q"], None, q_tp, None))
        k1 = jnp.einsum("bsd,dhk->bshk", h,
                        _gather_fsdp(plan, lpc["w_k"], None, kv_tp, None))
        v1 = jnp.einsum("bsd,dhk->bshk", h,
                        _gather_fsdp(plan, lpc["w_v"], None, kv_tp, None))
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"])
            k1 = rms_norm(k1, lp["k_norm"])
        q = rope(q, cos, sin)
        k1 = rope(k1, cos, sin)
        pos = (jnp.arange(sl["k"].shape[1])[None, :]
               == lengths[:, None])                  # (B, S) one-hot write
        kc = jnp.where(pos[:, :, None, None], k1.astype(CACHE_DTYPE),
                       sl["k"])
        vc = jnp.where(pos[:, :, None, None], v1.astype(CACHE_DTYPE),
                       sl["v"])
        o = decode_attention(q, kc, vc, lengths + 1)
        attn_out = jnp.einsum(
            "bshk,hkd->bsd", o,
            _gather_fsdp(plan, lpc["w_o"], q_tp, None, None))
        updates = {"k": kc, "v": vc}              # scan stacks the L axis
    x = x + attn_out.astype(x.dtype)
    h2 = rms_norm(x, lp["ln2"]).astype(cdt)
    x = x + _mlp_block(lpc, h2, cfg, plan, moe=moe).astype(x.dtype)
    return x, updates
