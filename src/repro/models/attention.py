"""Attention: dense, chunked (online-softmax), and single-token decode.

GQA throughout: q heads grouped over kv heads (MQA = 1 kv head).  The
chunked path is the TPU memory-efficient prefill attention — a
``lax.scan`` over KV blocks with running (max, denom, acc) in fp32, so the
(Sq, Sk) score tile never materializes for 32 k contexts (DESIGN.md §4).
Softmax statistics are always fp32 regardless of input dtype.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["attention", "decode_attention"]

_NEG = -1e30


def _group(q, n_kv):
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def attention(
    q: jnp.ndarray,                  # (B, Sq, Hq, dh)
    k: jnp.ndarray,                  # (B, Sk, Hkv, dh)
    v: jnp.ndarray,                  # (B, Sk, Hkv, dv)
    *,
    causal: bool = True,
    chunk: Optional[int] = None,     # KV block size; None = dense
    q_pos: Optional[jnp.ndarray] = None,    # (Sq,) global positions
    kv_mask: Optional[jnp.ndarray] = None,  # (B, Sk) 1 = valid
    scale: Optional[float] = None,
) -> jnp.ndarray:
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else dh ** -0.5
    qg = _group(q, hkv)                                   # (B,Sq,G,R,dh)
    qp = jnp.arange(sq) if q_pos is None else q_pos
    kp = jnp.arange(sk)

    if chunk is None or chunk >= sk:
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32)
        s = s * scale
        if causal:
            s = jnp.where(qp[:, None] >= kp[None, :], s, _NEG)
        if kv_mask is not None:
            s = jnp.where(kv_mask[:, None, None, None, :] > 0, s, _NEG)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v)
        return o.reshape(b, sq, hq, -1)

    n_blk = -(-sk // chunk)
    pad = n_blk * chunk - sk
    kpad = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vpad = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kvm = jnp.ones((b, sk), jnp.int32) if kv_mask is None else kv_mask
    kvm = jnp.pad(kvm, ((0, 0), (0, pad)))
    kb = kpad.reshape(b, n_blk, chunk, hkv, dh).swapaxes(0, 1)
    vb = vpad.reshape(b, n_blk, chunk, hkv, -1).swapaxes(0, 1)
    mb = kvm.reshape(b, n_blk, chunk).swapaxes(0, 1)

    dv = v.shape[-1]
    g, r = hkv, hq // hkv

    # flash-style backward: without the checkpoint, scan autodiff saves
    # every chunk's probability tile — reconstructing the full (Sq, Sk)
    # attention matrix in fp32 (8.6 GiB/layer on deepseek train_4k;
    # EXPERIMENTS.md §Perf). Rematting the step recomputes probs in the
    # backward pass from the carried (m, l, acc) statistics instead.
    @jax.checkpoint
    def step(carry, xs):
        m, l, acc = carry
        kc, vc, mc, blk = xs
        kpos = blk * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kc).astype(jnp.float32)
        s = s * scale
        if causal:
            s = jnp.where(qp[None, None, None, :, None]
                          >= kpos[None, None, None, None, :], s, _NEG)
        s = jnp.where(mc[:, None, None, None, :] > 0, s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bkgd->bgrqd", p.astype(q.dtype), vc
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, g, r, sq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, g, r, sq), jnp.float32)
    a0 = jnp.zeros((b, g, r, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kb, vb, mb, jnp.arange(n_blk))
    )
    o = acc / jnp.maximum(l[..., None], 1e-30)
    o = jnp.moveaxis(o, 3, 1)                             # (B,Sq,G,R,dv)
    return o.reshape(b, sq, hq, dv).astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,                  # (B, 1, Hq, dh)
    k_cache: jnp.ndarray,            # (B, S, Hkv, dh)
    v_cache: jnp.ndarray,            # (B, S, Hkv, dv)
    lengths: jnp.ndarray,            # (B,) valid cache length per sequence
    *,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """One-token attention over a (possibly sequence-sharded) KV cache."""
    b, s, hkv, dh = k_cache.shape
    hq = q.shape[2]
    scale = scale if scale is not None else dh ** -0.5
    qg = _group(q, hkv)                                   # (B,1,G,R,dh)
    sc = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k_cache).astype(jnp.float32)
    sc = sc * scale
    valid = jnp.arange(s)[None, :] < lengths[:, None]     # (B, S)
    sc = jnp.where(valid[:, None, None, None, :], sc, _NEG)
    p = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v_cache)
    return o.reshape(b, 1, hq, -1)
