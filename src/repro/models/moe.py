"""Mixture-of-Experts FFN (DeepSeek-V3 / Kimi-K2 style).

Router: sigmoid scores + aux-loss-free selection bias (bias enters top-k
selection only, not the gate value); selected gates renormalized and scaled
by ``routed_scaling``; one always-on shared expert.

Dispatch is GShard-style capacity-based, but built with the *sort* trick
instead of (T, E, C) one-hot einsums (those are O(T·E·C) memory): per
dispatch group, token→expert assignments are sorted by expert id, ranks
within each expert come from a searchsorted prefix, and tokens beyond
capacity C drop (`.at[].set(mode="drop")`).  The gathered (G, E, C, D)
activation is resharded group-major → expert-major with one explicit
``with_sharding_constraint``, which XLA lowers to the EP all-to-all on the
``ep`` mesh axes (DESIGN.md §4).  Experts whose id >= n_experts are mesh
padding (Kimi: 384 -> 512) and receive no tokens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, MoEConfig
from repro.distributed.sharding import ShardPlan
from repro.models.layers import swiglu

__all__ = ["moe_params", "moe_capacity", "moe_ffn"]


def moe_params(cfg: LMConfig, mk, plan: ShardPlan, prefix: str, stack: int):
    """Parameter description for ``stack`` scanned MoE layers."""
    m: MoEConfig = cfg.moe
    d, f, e = cfg.d_model, m.d_ff, m.e_pad
    fs = m.n_shared * m.d_ff
    L = (stack,)
    pp = lambda *dims: plan.p(None, *dims)   # leading layer dim unsharded

    return {
        "router": mk(f"{prefix}/router", L + (d, m.n_experts),
                     pp(None, None), init=("normal", 0.02),
                     param_dtype=jnp.float32),
        "router_bias": mk(f"{prefix}/router_bias", L + (m.n_experts,),
                          pp(None), init="zeros",
                          param_dtype=jnp.float32),
        "w_gate": mk(f"{prefix}/w_gate", L + (e, d, f),
                     pp("ep", None, None)),
        "w_up": mk(f"{prefix}/w_up", L + (e, d, f),
                   pp("ep", None, None)),
        "w_down": mk(f"{prefix}/w_down", L + (e, f, d),
                     pp("ep", None, None)),
        "sh_gate": mk(f"{prefix}/sh_gate", L + (d, fs),
                      pp("fsdp", "tp")),
        "sh_up": mk(f"{prefix}/sh_up", L + (d, fs), pp("fsdp", "tp")),
        "sh_down": mk(f"{prefix}/sh_down", L + (fs, d), pp("tp", "fsdp")),
    }


def moe_capacity(cfg: LMConfig, tokens_per_group: int) -> int:
    m = cfg.moe
    c = int(tokens_per_group * m.top_k / m.n_experts * m.capacity_factor)
    return max(8, -(-c // 8) * 8)


def _route(x, router_w, router_bias, moe_cfg: MoEConfig):
    """(T, D) -> (topk ids (T,K), gates fp32 (T,K))."""
    logits = jnp.einsum(
        "td,de->te", x.astype(jnp.float32), router_w.astype(jnp.float32)
    )
    if moe_cfg.score_fn == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    sel_scores = scores + router_bias[None, :]     # bias: selection only
    _, top_i = jax.lax.top_k(sel_scores, moe_cfg.top_k)
    top_s = jnp.take_along_axis(scores, top_i, axis=1)
    gates = top_s / jnp.maximum(top_s.sum(-1, keepdims=True), 1e-9)
    gates = gates * moe_cfg.routed_scaling
    return top_i.astype(jnp.int32), gates


def _dispatch_indices(top_i, e_pad: int, capacity: int):
    """Sort-based (E, C) token-slot table + per-slot flat assignment rank.

    Returns (dispatch (E, C) int32 token ids with T=dummy, slot_of (T*K,)
    pairs for combine: (expert, rank, keep)).
    """
    t, k = top_i.shape
    flat_e = top_i.reshape(-1)                              # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    ranks = jnp.arange(t * k, dtype=jnp.int32) - first.astype(jnp.int32)
    tok = (order // k).astype(jnp.int32)
    dispatch = jnp.full((e_pad, capacity), t, jnp.int32)
    dispatch = dispatch.at[sorted_e, ranks].set(tok, mode="drop")
    return dispatch, (sorted_e, ranks, order)


def moe_ffn(p, x, cfg: LMConfig, plan: ShardPlan):
    """x: (B, S, D) -> (B, S, D).  p: one layer's slice of ``moe_params``."""
    m = cfg.moe
    b, s, d = x.shape
    g = max(1, cfg.moe_groups)
    t_all = b * s
    assert t_all % g == 0, (t_all, g)
    tg = t_all // g
    cap = moe_capacity(cfg, tg)
    xt = x.reshape(g, tg, d)

    def group_dispatch(x_g):
        top_i, gates = _route(x_g, p["router"], p["router_bias"], m)
        dispatch, (sorted_e, ranks, order) = _dispatch_indices(
            top_i, m.e_pad, cap
        )
        x_pad = jnp.concatenate(
            [x_g, jnp.zeros((1, d), x_g.dtype)], axis=0
        )
        x_e = x_pad[dispatch]                               # (E, C, D)
        gate_flat = gates.reshape(-1)[order]
        g_e = jnp.zeros((m.e_pad, cap), jnp.float32)
        g_e = g_e.at[sorted_e, ranks].set(gate_flat, mode="drop")
        return x_e, g_e, dispatch

    x_e, g_e, dispatch = jax.vmap(group_dispatch)(xt)       # (G, E, C, D)

    # two-stage reshard (DESIGN.md §4): materialize the dispatch gather
    # (dp x tp)-sharded first — without this XLA materializes a per-chip
    # (1, E, C, D) tile (~10 GB on the giants) before the all-to-all.
    x_e = plan.constrain(x_e, "dp", "tp", None, None)
    # group-major -> expert-major: the EP all-to-all (groups stay sharded
    # over the pod axis; experts shard within the pod)
    x_e = plan.constrain(x_e, "pp", "ep", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", x_e, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", x_e, p["w_up"])
    y_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y_e = plan.constrain(y_e, "pp", "ep", None, None)
    y_e = plan.constrain(y_e, "dp", "tp", None, None)       # back to groups

    def group_combine(y_g, g_g, dispatch):
        w = (y_g * g_g[..., None].astype(y_g.dtype)).reshape(-1, d)
        out = jnp.zeros((tg + 1, d), y_g.dtype)
        out = out.at[dispatch.reshape(-1)].add(w)
        return out[:tg]

    y = jax.vmap(group_combine)(y_e, g_e, dispatch)         # (G, Tg, D)
    y = y.reshape(b, s, d)

    # always-on shared expert (FSDP-gather its weights before use)
    y = y + swiglu(
        x,
        plan.constrain(p["sh_gate"], None, "tp"),
        plan.constrain(p["sh_up"], None, "tp"),
        plan.constrain(p["sh_down"], "tp", None),
    )
    return y
