"""Shared NN layers: RMSNorm, RoPE, activations, losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "rope", "rope_tables", "swiglu", "gelu_mlp",
           "softmax_xent", "shifted_softplus"]


def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm in fp32, cast back to input dtype (LLaMA convention)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dt)


def rope_tables(positions, dim: int, theta: float = 1e6):
    """(..., dim/2) cos/sin tables for rotate-half RoPE."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv     # (..., dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def rope(x, cos, sin):
    """Rotate-half RoPE.

    x: (..., S, H, dim); cos/sin: (S, dim/2) or (B, S, dim/2) — a head axis
    is inserted second-to-last so tables broadcast over heads, and leading
    axes broadcast per normal numpy rules.
    """
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = jnp.expand_dims(cos, -2)
    sin = jnp.expand_dims(sin, -2)
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """LLaMA-style gated MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def gelu_mlp(x, w_up, w_down):
    """2-matrix GELU MLP (granite-34b code-model style)."""
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_up))
    return jnp.einsum("...f,fd->...d", h, w_down)


def shifted_softplus(x):
    """SchNet's ssp(x) = ln(0.5 e^x + 0.5)."""
    return jax.nn.softplus(x) - jnp.log(2.0)


def softmax_xent(logits, labels, *, z_loss: float = 0.0, mask=None):
    """Token-mean cross entropy in fp32 with optional z-loss.

    logits (..., V) any float dtype; labels int32 (...); mask broadcastable
    to labels (1 = count).  Returns (loss_scalar, aux dict).
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * lse**2
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((jnp.argmax(lf, -1) == labels) * mask).sum() / denom
    return loss, {"nll": loss, "accuracy": acc}
