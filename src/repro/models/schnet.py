"""SchNet: continuous-filter convolution GNN  [arXiv:1706.08566].

Message passing IS ``segment_sum`` over the edge list (taxonomy §GNN):
   m_e = (h[sender] W1) * filter(RBF(d_e));   h' += W2 ssp(segsum_e->recv m)

Edge arrays shard over the flattened (dp+tp) mesh axes; node states are
replicated — per-shard partial aggregates meet in the segment-sum's
all-reduce (DESIGN.md §4).  Two heads: per-node logits (citation-graph
shapes) and pooled per-graph energy (molecule shape).  The neighbor list
for molecule inputs comes from `core.graph_build.radius_graph` — the
paper's two-level machinery (DESIGN.md §5).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import SchNetConfig
from repro.distributed.sharding import ShardPlan
from repro.models import base
from repro.models.layers import shifted_softplus

__all__ = ["init", "param_specs", "param_shapes", "forward", "loss_fn"]


def _param_fn(cfg: SchNetConfig, mk, plan: ShardPlan):
    h, r = cfg.d_hidden, cfg.n_rbf
    L = (cfg.n_interactions,)
    pp = lambda *d: plan.p(*d)   # tiny params: replicated
    sp = lambda *d: plan.p(None, *d)
    return {
        "embed_in": mk("embed_in", (cfg.d_feat, h), pp(None, None)),
        "inter": {
            "w1": mk("inter/w1", L + (h, h), sp(None, None)),
            "f_w1": mk("inter/f_w1", L + (r, h), sp(None, None)),
            "f_b1": mk("inter/f_b1", L + (h,), sp(None), init="zeros"),
            "f_w2": mk("inter/f_w2", L + (h, h), sp(None, None)),
            "f_b2": mk("inter/f_b2", L + (h,), sp(None), init="zeros"),
            "w2": mk("inter/w2", L + (h, h), sp(None, None)),
            "b2": mk("inter/b2", L + (h,), sp(None), init="zeros"),
        },
        "head_w1": mk("head_w1", (h, h // 2), pp(None, None)),
        "head_b1": mk("head_b1", (h // 2,), pp(None), init="zeros"),
        "head_w2": mk("head_w2", (h // 2, cfg.n_out), pp(None, None)),
    }


def init(cfg: SchNetConfig, key, plan: ShardPlan = ShardPlan()):
    return base.build_params(partial(_param_fn, plan=plan), cfg, key)


def param_specs(cfg: SchNetConfig, plan: ShardPlan):
    return base.build_specs(partial(_param_fn, plan=plan), cfg)


def param_shapes(cfg: SchNetConfig, plan: ShardPlan):
    return base.build_shapes(partial(_param_fn, plan=plan), cfg)


def _rbf(dist, cfg: SchNetConfig):
    """Gaussian radial basis on [0, cutoff], gamma from center spacing."""
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    gamma = 1.0 / (centers[1] - centers[0]) ** 2
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def forward(params, batch, cfg: SchNetConfig,
            plan: ShardPlan = ShardPlan()):
    """batch: {feats (N,F), pos (N,3), senders (E,), receivers (E,),
    [graph_ids (N,) + n_graphs]} -> per-node hidden (N, h).

    senders/receivers use -1 for padded edges (masked out of the message
    sum).
    """
    feats, pos = batch["feats"], batch["pos"]
    snd, rcv = batch["senders"], batch["receivers"]
    n = feats.shape[0]
    h = jnp.einsum("nf,fh->nh", feats, params["embed_in"])
    if cfg.message_dtype == "bfloat16":
        # node state in bf16 too: the *gradient* all-reduces (cotangent of
        # the replicated node state w.r.t. sharded edges) follow h's dtype
        h = h.astype(jnp.bfloat16)

    edge_valid = (snd >= 0) & (rcv >= 0)
    s_safe = jnp.maximum(snd, 0)
    r_safe = jnp.maximum(rcv, 0)
    dvec = pos[s_safe] - pos[r_safe]
    dist = jnp.sqrt(jnp.maximum((dvec * dvec).sum(-1), 1e-12))
    rbf = _rbf(dist, cfg)                                   # (E, R)
    # smooth cosine cutoff envelope
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cfg.cutoff, 0, 1)) + 1.0)
    rbf = plan.constrain(rbf * env[:, None], ("dp", "tp"), None)

    mdt = jnp.bfloat16 if cfg.message_dtype == "bfloat16" else jnp.float32

    def interaction(h, ip):
        hw = jnp.einsum("nh,hg->ng", h, ip["w1"])
        w = shifted_softplus(rbf @ ip["f_w1"] + ip["f_b1"])
        w = shifted_softplus(w @ ip["f_w2"] + ip["f_b2"])   # (E, h)
        # messages AND everything until the residual add stay in
        # message_dtype, so the cross-shard partial-aggregate all-reduce
        # carries bf16 (an early .astype(f32) gets fused into the scatter
        # and the AR runs f32 — measured, EXPERIMENTS.md §Perf iter 13)
        m = hw[s_safe].astype(mdt) * w.astype(mdt)
        m = jnp.where(edge_valid[:, None], m, jnp.zeros((), mdt))
        agg = jax.ops.segment_sum(m, r_safe, num_segments=n)
        upd = jnp.einsum("nh,hg->ng", shifted_softplus(agg),
                         ip["w2"].astype(mdt))
        return h + upd.astype(h.dtype) + ip["b2"]

    for i in range(cfg.n_interactions):
        h = interaction(h, jax.tree.map(lambda a: a[i], params["inter"]))
    return h


def node_logits(params, h):
    z = shifted_softplus(h @ params["head_w1"] + params["head_b1"])
    return z @ params["head_w2"]


def graph_energy(params, h, graph_ids, n_graphs: int):
    z = node_logits(params, h)[:, 0]                        # atomwise energy
    return jax.ops.segment_sum(z, jnp.maximum(graph_ids, 0),
                               num_segments=n_graphs)


def loss_fn(params, batch, cfg: SchNetConfig,
            plan: ShardPlan = ShardPlan()):
    """Node-classification CE when batch has 'labels'; energy MSE when it
    has 'energy' (+ graph_ids/n_graphs)."""
    h = forward(params, batch, cfg, plan)
    if "labels" in batch:
        logits = node_logits(params, h)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        ll = jnp.take_along_axis(lf, batch["labels"][:, None], 1)[:, 0]
        mask = batch.get("node_mask",
                         jnp.ones_like(ll)).astype(jnp.float32)
        loss = ((lse - ll) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        acc = ((jnp.argmax(lf, -1) == batch["labels"]) * mask).sum() \
            / jnp.maximum(mask.sum(), 1.0)
        return loss, {"loss": loss, "accuracy": acc}
    n_graphs = batch["energy"].shape[0]        # static via shape
    e = graph_energy(params, h, batch["graph_ids"], n_graphs)
    loss = jnp.mean((e - batch["energy"]) ** 2)
    return loss, {"loss": loss}
