"""EmbeddingBag and sharded-table helpers.

JAX has no native EmbeddingBag (taxonomy §RecSys): we implement it as
``jnp.take`` + mask/segment reduction.  Table sharding policy (DESIGN.md
§4): column-shard (embed dim over tp) when dim % tp == 0 — lookups stay
local, each chip holds a dim-slice of every row; otherwise row-shard over
tp (XLA SPMD turns the gather into a one-hot-select + all-reduce).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["embedding_bag", "take_embeddings", "concat_table_offsets"]


def take_embeddings(table, ids):
    """Row gather with -1 = padding (returns zeros)."""
    safe = jnp.maximum(ids, 0)
    out = jnp.take(table, safe, axis=0)
    return jnp.where((ids >= 0)[..., None], out, 0.0)


def embedding_bag(table, ids, *, weights=None, mode: str = "sum"):
    """ids (..., L) with -1 padding -> (..., D) reduced embeddings."""
    e = take_embeddings(table, ids)                       # (..., L, D)
    if weights is not None:
        e = e * weights[..., None]
    if mode == "sum":
        return e.sum(axis=-2)
    if mode == "mean":
        n = jnp.maximum((ids >= 0).sum(axis=-1, keepdims=True), 1)
        return e.sum(axis=-2) / n
    if mode == "max":
        e = jnp.where((ids >= 0)[..., None], e, -jnp.inf)
        out = e.max(axis=-2)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(f"unknown mode {mode!r}")


def concat_table_offsets(table_sizes):
    """Offsets for fusing per-feature tables into one big table.

    MLPerf-DLRM-style: 26 tables become one (sum_rows, D) array; feature j's
    id i maps to row offsets[j] + i — one gather instead of 26.
    """
    import numpy as np

    off = np.zeros(len(table_sizes), dtype=np.int64)
    np.cumsum(np.asarray(table_sizes)[:-1], out=off[1:])
    return off, int(sum(table_sizes))
