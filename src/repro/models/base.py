"""Functional parameter system (no flax in this environment).

A model describes its parameters once, through a ``mk`` callback::

    def params(cfg, mk):
        return {"w": mk("w", (d, f), P("data", "model"), init="fanin"), ...}

and two interpreters consume the description:

  * ``build_params(fn, cfg, key)``  -> pytree of initialized jnp arrays
  * ``build_specs(fn, cfg)``        -> identically-structured PartitionSpec
                                       pytree (used for in_shardings and for
                                       optimizer-state sharding)

Param rngs are derived by folding a stable hash of the parameter name into
the root key, so adding parameters never reshuffles existing inits.
"""
from __future__ import annotations

import hashlib
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["build_params", "build_specs", "P", "count_params",
           "cast_tree", "tree_bytes"]


def _name_fold(key, name: str):
    h = int.from_bytes(hashlib.md5(name.encode()).digest()[:4], "little")
    return jax.random.fold_in(key, h)


def _init_array(key, shape, init, dtype):
    if init == "zeros":
        return jnp.zeros(shape, dtype)
    if init == "ones":
        return jnp.ones(shape, dtype)
    if isinstance(init, (int, float)):
        return jnp.full(shape, float(init), dtype)
    if init == "fanin":  # variance scaling, fan_in, truncated-normal-ish
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = (1.0 / max(1, fan_in)) ** 0.5
        return (jax.random.normal(key, shape) * std).astype(dtype)
    if isinstance(init, tuple) and init[0] == "normal":
        return (jax.random.normal(key, shape) * init[1]).astype(dtype)
    raise ValueError(f"unknown init {init!r}")


def build_params(fn: Callable, cfg, key, dtype=jnp.float32):
    def mk(name, shape, spec, init="fanin", param_dtype=None):
        del spec
        return _init_array(
            _name_fold(key, name), shape, init, param_dtype or dtype
        )

    return fn(cfg, mk)


def build_specs(fn: Callable, cfg):
    def mk(name, shape, spec, init="fanin", param_dtype=None):
        del name, shape, init, param_dtype
        return spec if spec is not None else P()

    return fn(cfg, mk)


def build_shapes(fn: Callable, cfg, dtype=jnp.float32):
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    def mk(name, shape, spec, init="fanin", param_dtype=None):
        del name, spec, init
        return jax.ShapeDtypeStruct(shape, param_dtype or dtype)

    return fn(cfg, mk)


def count_params(tree) -> int:
    return sum(
        int(jnp.size(x)) if hasattr(x, "size") else 0
        for x in jax.tree.leaves(tree)
    )


def tree_bytes(tree) -> int:
    return sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
        if hasattr(x, "dtype")
    )


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x,
        tree,
    )
