"""Request tracing: spans, propagated trace ids, Chrome-trace export.

The fleet's request path crosses three thread domains — the caller
(router admission), the cell's batch worker (micro-batch assembly +
backend dispatch), and the backend's device work — so a single latency
number can't say *where* a p99 went.  A :class:`Tracer` records
**spans** (named, timed intervals with attributes) into a bounded ring
buffer and exports them as Chrome-trace JSON, which Perfetto
(https://ui.perfetto.dev) renders as a per-thread timeline.

Two recording modes cover the two threading shapes:

* :meth:`Tracer.span` — a context manager for work done on the current
  thread.  Spans nest via a thread-local stack; a child inherits its
  parent's ``trace_id`` so every event of one request shares an id.
* :meth:`Tracer.record_span` — explicit ``(t_start, t_end)`` recording
  for intervals that *end* on a different thread than they began (the
  queue wait starts at ``submit`` on the caller thread and ends when
  the batch worker picks the request up — the worker records it).

The span taxonomy instrumented across the stack (``route`` >
``admission``, ``queue``, ``batch`` > ``dispatch`` > ``kernel`` >
``rerank``, plus ``maint.*`` and ``republish``) is catalogued in
``docs/observability.md``.

Design constraints, inherited from the serving stack's invariants:

* **bounded memory** — the ring holds ``capacity`` events; sustained
  traffic overwrites the oldest (``n_dropped`` counts evictions);
* **zero jit surface** — tracing is pure host bookkeeping (two
  ``perf_counter`` calls and a dict append per span).  It cannot
  introduce a compile signature, and the recompile gate runs with it
  enabled;
* **never throws into the traced path** — a span body's exception is
  tagged on the span (``error`` attribute) and re-raised untouched.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Optional

__all__ = ["Tracer", "get_tracer", "set_tracer"]


class _Span:
    """Mutable handle yielded by :meth:`Tracer.span`; ``set(**attrs)``
    attaches attributes that land in the exported event's ``args``."""

    __slots__ = ("name", "span_id", "trace_id", "parent_id", "t0",
                 "tid", "attrs")

    def __init__(self, name, span_id, trace_id, parent_id, t0, tid,
                 attrs):
        self.name = name
        self.span_id = span_id
        self.trace_id = trace_id
        self.parent_id = parent_id
        self.t0 = t0
        self.tid = tid
        self.attrs = attrs

    def set(self, **attrs) -> "_Span":
        self.attrs.update(attrs)
        return self


class _NullSpan:
    """Yielded when tracing is disabled: absorbs ``set`` calls."""

    __slots__ = ()
    trace_id = 0
    span_id = 0

    def set(self, **attrs):
        return self


_NULL = _NullSpan()


class Tracer:
    """Bounded in-process span recorder with Chrome-trace export."""

    def __init__(self, capacity: int = 32768, enabled: bool = True):
        self.capacity = int(capacity)
        self.enabled = enabled
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=self.capacity)
        self._n_emitted = 0
        self._ids = itertools.count(1)
        self._tls = threading.local()
        self._t0 = time.perf_counter()
        self._wall0 = time.time()

    # -- id / context plumbing -----------------------------------------
    def new_trace_id(self) -> int:
        return next(self._ids)

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def current_span(self) -> Optional[_Span]:
        st = self._stack()
        return st[-1] if st else None

    # -- recording -----------------------------------------------------
    @contextmanager
    def span(self, name: str, *, trace_id: Optional[int] = None, **attrs):
        """Time a block on the current thread; nests under the
        enclosing span and inherits its ``trace_id`` unless one is
        passed explicitly."""
        if not self.enabled:
            yield _NULL
            return
        stack = self._stack()
        parent = stack[-1] if stack else None
        if trace_id is None:
            trace_id = parent.trace_id if parent else self.new_trace_id()
        sp = _Span(name, next(self._ids), trace_id,
                   parent.span_id if parent else 0,
                   time.perf_counter(), threading.get_ident(),
                   dict(attrs))
        stack.append(sp)
        try:
            yield sp
        except BaseException as e:
            sp.attrs.setdefault("error", type(e).__name__)
            raise
        finally:
            stack.pop()
            self._emit(sp.name, sp.t0, time.perf_counter(), sp.trace_id,
                       sp.span_id, sp.parent_id, sp.tid, sp.attrs)

    def record_span(self, name: str, t_start: float, t_end: float, *,
                    trace_id: int = 0, tid: Optional[int] = None,
                    **attrs) -> None:
        """Record an already-timed interval (``perf_counter`` seconds).

        The cross-thread form: the queue wait is *started* by the
        caller's ``submit`` and *recorded* by the batch worker, under
        the worker's tid, keyed back to the request by ``trace_id``.
        """
        if not self.enabled:
            return
        parent = self.current_span()
        self._emit(name, t_start, t_end, trace_id,
                   next(self._ids), parent.span_id if parent else 0,
                   tid if tid is not None else threading.get_ident(),
                   attrs)

    def instant(self, name: str, **attrs) -> None:
        """Zero-duration marker (hedge fired, compile happened, ...)."""
        if not self.enabled:
            return
        parent = self.current_span()
        now = time.perf_counter()
        ev = {"ph": "i", "s": "t", "name": name, "pid": 0,
              "tid": threading.get_ident(),
              "ts": (now - self._t0) * 1e6,
              "args": dict(attrs,
                           trace_id=parent.trace_id if parent else 0)}
        with self._lock:
            self._events.append(ev)
            self._n_emitted += 1

    def _emit(self, name, t0, t1, trace_id, span_id, parent_id, tid,
              attrs) -> None:
        args = dict(attrs)
        args["trace_id"] = trace_id
        args["span_id"] = span_id
        if parent_id:
            args["parent"] = parent_id
        ev = {"ph": "X", "name": name, "cat": "repro", "pid": 0,
              "tid": tid, "ts": (t0 - self._t0) * 1e6,
              "dur": max((t1 - t0) * 1e6, 0.0), "args": args}
        with self._lock:
            self._events.append(ev)
            self._n_emitted += 1

    # -- introspection / export ----------------------------------------
    @property
    def n_dropped(self) -> int:
        with self._lock:
            return max(0, self._n_emitted - len(self._events))

    def events(self, name: Optional[str] = None) -> list:
        with self._lock:
            evs = list(self._events)
        return evs if name is None else [e for e in evs
                                         if e["name"] == name]

    def span_names(self) -> set:
        with self._lock:
            return {e["name"] for e in self._events}

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._n_emitted = 0

    def footprint_capacity(self) -> int:
        """The hard event cap — the bounded-memory contract."""
        return self.capacity

    def to_chrome(self) -> dict:
        """Chrome-trace JSON object: load at ui.perfetto.dev or
        chrome://tracing.  ``ts`` is microseconds from tracer start."""
        return {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {
                "recorder": "repro.obs.trace",
                "wall_time_origin_unix_s": self._wall0,
                "events_dropped": self.n_dropped,
            },
        }

    def export(self, path: str) -> str:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.to_chrome(), f)
        return path


_GLOBAL = Tracer()


def get_tracer() -> Tracer:
    """The process-wide default tracer (components take ``tracer=`` to
    override; benchmarks install a fresh one via :func:`set_tracer`)."""
    return _GLOBAL


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process default; returns the previous tracer so callers
    can restore it (``finally: set_tracer(old)``)."""
    global _GLOBAL
    old, _GLOBAL = _GLOBAL, tracer
    return old
