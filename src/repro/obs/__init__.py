"""End-to-end observability: metrics registry, request tracing, and
kernel/compile profiling.

Three submodules, one per tentpole concern:

* :mod:`repro.obs.metrics` — fixed-footprint counters/gauges/log-scale
  histograms with JSON snapshot + Prometheus text exposition;
* :mod:`repro.obs.trace` — span API with propagated trace ids and
  Chrome-trace/Perfetto export;
* :mod:`repro.obs.profile` — compile-event accounting, per-entry-point
  replay profiling, and the analytic bytes/FLOPs cost model.

See ``docs/observability.md`` for the metric catalog and span taxonomy.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               merge_snapshots, parse_exposition)
from repro.obs.profile import (PROFILE, backend_cost,
                               install_jax_compile_hooks,
                               profile_entry_points)
from repro.obs.trace import Tracer, get_tracer, set_tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "PROFILE",
    "Tracer",
    "backend_cost",
    "get_tracer",
    "install_jax_compile_hooks",
    "merge_snapshots",
    "parse_exposition",
    "profile_entry_points",
    "set_tracer",
]
