"""Low-overhead metrics registry: counters, gauges, log-scale histograms.

The serving stack used to keep telemetry in unbounded Python lists
(``ServingCell.latencies`` grew one float per request, forever) and a
scatter of ``_stats_lock``-guarded ints.  This module replaces both with
three fixed-footprint instruments:

``Counter``
    Monotone int64, internally locked.  ``inc(n)`` / ``.value``.
``Gauge``
    Last-write float, internally locked.  ``set(v)`` / ``.value``.
``Histogram``
    Fixed-bucket **log-scale** histogram on a preallocated numpy int64
    array — observing ten requests or ten billion costs the same bytes.
    Buckets are geometric (``per_decade`` buckets per factor of 10
    between ``lo`` and ``hi``), so quantile estimates carry a bounded
    *relative* error of one bucket ratio (~12% at the default
    ``per_decade=20``) across the whole dynamic range — the right trade
    for latencies, where 100us and 100ms matter equally.  Exact
    ``sum``/``count``/``min``/``max`` ride along, so means are exact and
    quantiles clamp to the observed range.

Every instrument owns a private ``threading.Lock`` — callers never wrap
metric updates in their own locks (the ``repro.analysis`` lock lint
knows this and exempts instrument mutations from the per-class lock
discipline).  A :class:`MetricsRegistry` is a named, get-or-create
collection with two serializations:

* :meth:`MetricsRegistry.snapshot` — JSON-safe dict (counters as ints,
  histograms as count/sum/min/max/p50/p90/p99 + sparse bucket pairs);
* :meth:`MetricsRegistry.exposition` — Prometheus text format
  (cumulative ``_bucket{le=...}`` series), round-trippable through
  :func:`parse_exposition` for scrape-pipeline tests.

See ``docs/observability.md`` for the metric catalog.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Dict, Iterable, Optional

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "merge_snapshots",
    "parse_exposition",
]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def sanitize(name: str) -> str:
    """Prometheus-legal metric name (dots and dashes become ``_``)."""
    out = _NAME_RE.sub("_", name)
    return out if not out[:1].isdigit() else "_" + out


class Counter:
    """Monotone counter.  Internally locked: safe to ``inc`` from any
    thread without holding the owner's lock."""

    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += int(n)

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    def footprint_bytes(self) -> int:
        return 64

    def to_snapshot(self):
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-write-wins float gauge.  Internally locked."""

    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += float(n)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def footprint_bytes(self) -> int:
        return 64

    def to_snapshot(self):
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-memory log-scale histogram.

    ``edges[i]`` is the inclusive upper bound of bucket ``i``
    (Prometheus ``le`` semantics); one extra overflow bucket catches
    ``v > hi``, and ``v <= lo`` lands in bucket 0 — the footprint is
    fixed at construction no matter what is observed.  Non-finite
    observations are dropped (counted in ``n_dropped``) rather than
    poisoning sum/min/max.
    """

    kind = "histogram"

    def __init__(self, name: str, *, lo: float = 1e-3, hi: float = 1e5,
                 per_decade: int = 20):
        if not (0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
        self.name = name
        self.lo = float(lo)
        self.hi = float(hi)
        n_edges = max(1, round(per_decade * math.log10(hi / lo))) + 1
        self.edges = np.geomspace(lo, hi, num=n_edges)
        self.edges[-1] = hi                     # kill geomspace rounding
        self._counts = np.zeros(n_edges + 1, np.int64)   # + overflow
        self._lock = threading.Lock()
        self._sum = 0.0
        self._count = 0
        self._min = math.inf
        self._max = -math.inf
        self.n_dropped = 0

    # -- writes --------------------------------------------------------
    def observe(self, v: float) -> None:
        v = float(v)
        if not math.isfinite(v):
            with self._lock:
                self.n_dropped += 1
            return
        idx = int(np.searchsorted(self.edges, v, side="left"))
        with self._lock:
            self._counts[idx] += 1
            self._sum += v
            self._count += 1
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    # -- reads ---------------------------------------------------------
    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def _state(self):
        with self._lock:
            return (self._counts.copy(), self._count, self._sum,
                    self._min, self._max)

    def quantile(self, q: float) -> float:
        """Approximate quantile: log-interpolated within the covering
        bucket, clamped to the exact observed [min, max]."""
        counts, count, _, vmin, vmax = self._state()
        if count == 0:
            return 0.0
        target = q * count
        cum = np.cumsum(counts)
        j = int(np.searchsorted(cum, max(target, 1e-12), side="left"))
        j = min(j, len(counts) - 1)
        lo_b = self.edges[j - 1] if j >= 1 else self.lo / \
            (self.edges[1] / self.edges[0])
        hi_b = self.edges[j] if j < len(self.edges) else max(vmax, self.hi)
        prev = cum[j - 1] if j >= 1 else 0
        in_bucket = counts[j] if counts[j] else 1
        frac = min(max((target - prev) / in_bucket, 0.0), 1.0)
        if lo_b > 0 and hi_b > lo_b:
            val = lo_b * (hi_b / lo_b) ** frac
        else:
            val = lo_b + (hi_b - lo_b) * frac
        return float(min(max(val, vmin), vmax))

    def percentiles(self, qs: Iterable[float] = (0.5, 0.9, 0.99)) -> dict:
        return {f"p{int(q * 100)}": self.quantile(q) for q in qs}

    def footprint_bytes(self) -> int:
        return int(self._counts.nbytes + self.edges.nbytes + 128)

    def stats_dict(self) -> dict:
        """The per-stage summary shape ``EngineStats.stages`` carries."""
        counts, count, total, vmin, vmax = self._state()
        if count == 0:
            return {"n": 0, "p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
        return {"n": int(count),
                "p50_ms": self.quantile(0.5),
                "p99_ms": self.quantile(0.99),
                "mean_ms": total / count}

    def to_snapshot(self):
        counts, count, total, vmin, vmax = self._state()
        nz = np.nonzero(counts)[0]
        buckets = [[(float(self.edges[i]) if i < len(self.edges)
                     else math.inf), int(counts[i])] for i in nz]
        out = {"type": "histogram", "count": int(count),
               "sum": float(total), "buckets": buckets}
        if count:
            out.update(min=float(vmin), max=float(vmax),
                       p50=self.quantile(0.5), p90=self.quantile(0.9),
                       p99=self.quantile(0.99))
        return out

    @classmethod
    def merged(cls, name: str, hists: "Iterable[Histogram]") -> "Histogram":
        """Sum identically-bucketed histograms (fleet aggregation)."""
        hists = list(hists)
        if not hists:
            return cls(name)
        h0 = hists[0]
        out = cls(name, lo=h0.lo, hi=h0.hi)
        out.edges = h0.edges.copy()
        out._counts = np.zeros(len(h0._counts), np.int64)
        for h in hists:
            if len(h._counts) != len(out._counts):
                raise ValueError(
                    f"cannot merge {h.name}: bucket layout differs")
            counts, count, total, vmin, vmax = h._state()
            out._counts += counts
            out._count += count
            out._sum += total
            out._min = min(out._min, vmin)
            out._max = max(out._max, vmax)
        return out


class MetricsRegistry:
    """Named get-or-create collection of instruments.

    The registry lock only guards the name table — each instrument is
    internally locked, so the hot path (``counter(...)`` once at
    construction, ``inc()``/``observe()`` per event) never contends on
    registry-wide state.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: "Dict[str, object]" = {}

    def _get_or_create(self, name: str, factory, kind: str):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            elif m.kind != kind:
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {kind}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, lambda: Counter(name), "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, lambda: Gauge(name), "gauge")

    def histogram(self, name: str, *, lo: float = 1e-3, hi: float = 1e5,
                  per_decade: int = 20) -> Histogram:
        return self._get_or_create(
            name, lambda: Histogram(name, lo=lo, hi=hi,
                                    per_decade=per_decade), "histogram")

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> list:
        with self._lock:
            return sorted(self._metrics)

    def _items(self):
        with self._lock:
            return sorted(self._metrics.items())

    def footprint_bytes(self) -> int:
        """Fixed-size proof: the sum is invariant under any number of
        observations (the bounded-telemetry regression test pins this)."""
        return sum(m.footprint_bytes() for _, m in self._items())

    # -- serialization -------------------------------------------------
    def snapshot(self, prefix: str = "") -> dict:
        return {prefix + name: m.to_snapshot() for name, m in self._items()}

    def exposition(self, prefix: str = "") -> str:
        """Prometheus text exposition (cumulative ``le`` buckets)."""
        lines = []
        for name, m in self._items():
            pname = sanitize(prefix + name)
            if m.kind == "counter":
                lines += [f"# TYPE {pname} counter",
                          f"{pname}_total {m.value}"]
            elif m.kind == "gauge":
                lines += [f"# TYPE {pname} gauge",
                          f"{pname} {m.value:.9g}"]
            else:
                counts, count, total, _, _ = m._state()
                lines.append(f"# TYPE {pname} histogram")
                cum = 0
                for i, c in enumerate(counts):
                    cum += int(c)
                    le = (f"{m.edges[i]:.9g}" if i < len(m.edges)
                          else "+Inf")
                    lines.append(f'{pname}_bucket{{le="{le}"}} {cum}')
                lines.append(f"{pname}_sum {total:.9g}")
                lines.append(f"{pname}_count {count}")
        return "\n".join(lines) + "\n"


def parse_exposition(text: str) -> dict:
    """Parse :meth:`MetricsRegistry.exposition` output back into
    ``{name: {"type", ...}}`` — the scrape-side half of the round-trip
    test (and a sanity check that the text really is Prometheus-shaped).
    """
    out: dict = {}
    types: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                types[parts[2]] = parts[3]
                out[parts[2]] = {"type": parts[3]}
                if parts[3] == "histogram":
                    out[parts[2]]["buckets"] = {}
            continue
        key, _, val = line.rpartition(" ")
        m = re.match(r'^([a-zA-Z0-9_:]+)_bucket\{le="([^"]+)"\}$', key)
        if m:
            out.setdefault(m.group(1), {"type": "histogram",
                                        "buckets": {}})
            out[m.group(1)]["buckets"][m.group(2)] = int(val)
            continue
        for suffix, field, cast in (("_sum", "sum", float),
                                    ("_count", "count", int),
                                    ("_total", "value", int)):
            base = key[:-len(suffix)]
            if key.endswith(suffix) and types.get(base) in (
                    "histogram", "counter"):
                out.setdefault(base, {"type": types[base]})[field] = \
                    cast(val)
                break
        else:
            if types.get(key) == "gauge":
                out.setdefault(key, {"type": "gauge"})["value"] = \
                    float(val)
    return out


def merge_snapshots(parts: "Dict[str, MetricsRegistry]") -> dict:
    """One JSON-safe snapshot over many registries: ``parts`` maps a
    prefix (``"cell0."``) to its registry — the fleet/smoke view."""
    out: dict = {}
    for prefix, reg in sorted(parts.items()):
        out.update(reg.snapshot(prefix=prefix))
    return out
