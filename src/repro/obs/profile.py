"""Kernel/compile profiling hooks: compile events, per-dispatch device
time, and analytic-vs-measured roofline accounting.

Three layers, cheapest first:

1. **Compile signatures** (always on) — ``ShardedSearchBackend`` tracks
   the abstract signature (shape x dtype) of every query batch it
   dispatches; the *first* call per signature is the one that paid a
   trace+compile, so its wall time and signature are recorded
   (``compile_signatures`` counter, ``first_call_ms`` histogram, a
   ``compile-signature`` instant in the trace).  A healthy serving cell
   stops accruing signatures after its pow2 warm-up — the same invariant
   ``repro.analysis``'s recompile gate enforces, now observable in
   production telemetry.

2. **JAX monitoring hooks** (:func:`install_jax_compile_hooks`) — JAX
   emits ``/jax/core/compile/...`` duration events at every real XLA
   compile; the listener mirrors them into the process-wide
   :data:`PROFILE` registry and the active tracer.  Registration is
   idempotent and survives for the process lifetime (JAX has no
   unregister), so the listener reads the *current* default tracer at
   event time.

3. **Entry-point accounting** (:func:`profile_entry_points`) — replays
   the jitted entry points registered in
   :mod:`repro.analysis.registry` (the same list the recompile gate
   checks), wall-timing every lifecycle step and attributing
   compiled-variant growth to the step that triggered it.

The analytic side (:func:`backend_cost`) prices one dispatch of a
backend in bytes/FLOPs using the same traffic model as
``benchmarks/roofline.py``'s ``ann_scan_rows`` — so the fused, unfused
and int8 paths report a *measured* achieved-bandwidth number next to the
*analytic* useful-byte fraction, per backend, from live telemetry
(``ShardedSearchBackend.roofline_report``).
"""
from __future__ import annotations

import time
from typing import Iterable, Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import get_tracer

__all__ = [
    "PROFILE",
    "backend_cost",
    "install_jax_compile_hooks",
    "profile_entry_points",
]

# process-wide profiling registry: compile events land here regardless
# of which component triggered them (there is one XLA compiler queue)
PROFILE = MetricsRegistry()

_HOOKS_INSTALLED = False


def install_jax_compile_hooks(metrics: Optional[MetricsRegistry] = None,
                              ) -> bool:
    """Mirror JAX's compile-duration monitoring events into ``metrics``
    (default :data:`PROFILE`) and the current default tracer.

    Returns True when the listener is (already) installed, False when
    this jax build has no monitoring surface.  Idempotent — JAX offers
    no per-listener unregister, so exactly one process-wide listener is
    ever added and it routes through module state.
    """
    global _HOOKS_INSTALLED
    reg = metrics or PROFILE
    if _HOOKS_INSTALLED:
        return True
    try:
        from jax import monitoring
    except ImportError:                       # pragma: no cover
        return False
    if not hasattr(monitoring, "register_event_duration_secs_listener"):
        return False                          # pragma: no cover

    def _on_duration(event: str, secs: float, **kw) -> None:
        if "compile" not in event:
            return
        reg.counter("jax_compile_events").inc()
        reg.histogram("jax_compile_ms", lo=1e-2, hi=1e6).observe(
            secs * 1e3)
        get_tracer().instant("jax-compile", event=event,
                             ms=round(secs * 1e3, 3))

    monitoring.register_event_duration_secs_listener(_on_duration)
    _HOOKS_INSTALLED = True
    return True


def backend_cost(kind: str, *, fused: bool, precision: str,
                 n_rows: int, d: int, b: int, k: int,
                 n_probe_rows: int = 0, n_centroids: int = 0) -> dict:
    """Analytic bytes/FLOPs for ONE dispatch of a sharded search.

    Mirrors ``benchmarks/roofline.py:ann_scan_rows``: the scan is
    bandwidth-bound, so variants differ almost purely in bytes moved —
    ``useful_bytes`` is the corpus traffic a perfect kernel must move,
    ``bytes_moved`` adds the materialized ``(B, N)`` distance matrix
    (write + read-back) that only the *unfused* brute path pays.

    ``n_rows`` is total corpus rows placed; for ``ivf``/``forest`` the
    probed subset (``n_probe_rows``) plus the centroid scan
    (``n_centroids``) is what actually moves per query batch — an
    estimate (probe sets overlap across a batch), flagged as such in
    the report.
    """
    if kind == "brute":
        scanned = n_rows
        db_bytes = (n_rows * d * 1.0 + n_rows * 4.0
                    if precision == "int8" else n_rows * d * 4.0)
    else:
        scanned = n_probe_rows
        db_bytes = (n_centroids + n_probe_rows) * d * 4.0
    out_bytes = b * k * 8.0
    moved = db_bytes + out_bytes
    if kind == "brute" and not fused:
        moved += 2.0 * b * scanned * 4.0     # (B, N) write + read-back
    flops = 2.0 * b * (n_centroids + scanned) * d
    return {
        "kind": kind, "fused": bool(fused), "precision": precision,
        "useful_bytes": db_bytes, "bytes_moved": moved,
        "flops": flops,
        "analytic_frac": db_bytes / moved if moved else 0.0,
        "estimate": kind != "brute",
    }


def profile_entry_points(names: Optional[Iterable[str]] = None, *,
                         metrics: Optional[MetricsRegistry] = None,
                         ) -> dict:
    """Replay registered jitted entry points, accounting per step.

    For each entry point in :mod:`repro.analysis.registry` (or the
    ``names`` subset): build its Plan, run the steps in order, and
    record per step the wall time and the compiled-variant growth its
    mutations triggered.  Returns ``{name: {"steps": [...],
    "compiles": int, "wall_ms": float}}`` and mirrors the numbers into
    ``metrics`` (default :data:`PROFILE`) + spans into the tracer —
    the per-entry-point compile ledger the ISSUE's tuner work reads.
    """
    from repro.analysis.registry import ENTRY_POINTS

    install_jax_compile_hooks(metrics)
    reg = metrics or PROFILE
    tracer = get_tracer()
    chosen = sorted(ENTRY_POINTS) if names is None else list(names)
    report: dict = {}
    for name in chosen:
        builder = ENTRY_POINTS[name]
        steps_out: list = []
        t_entry = time.perf_counter()
        with tracer.span("profile.entry-point", entry=name):
            try:
                plan = builder()
            except Exception as e:
                report[name] = {"error": repr(e), "steps": [],
                                "compiles": 0, "wall_ms": 0.0}
                continue
            prev = None
            compiles = 0
            for label, thunk in plan.steps:
                t0 = time.perf_counter()
                with tracer.span("profile.step", entry=name, step=label):
                    thunk()
                wall_ms = (time.perf_counter() - t0) * 1e3
                size = plan.cache_size()
                grew = (0 if size < 0 or prev is None
                        else max(size - prev, 0))
                prev = size if size >= 0 else prev
                compiles += grew
                steps_out.append({"label": label,
                                  "wall_ms": round(wall_ms, 3),
                                  "cache_size": size,
                                  "new_compiles": grew})
                reg.histogram(f"entry.{name}.step_ms",
                              lo=1e-3, hi=1e7).observe(wall_ms)
        wall_ms = (time.perf_counter() - t_entry) * 1e3
        reg.counter(f"entry.{name}.compiles").inc(compiles)
        report[name] = {"steps": steps_out, "compiles": compiles,
                        "wall_ms": round(wall_ms, 3)}
    return report
