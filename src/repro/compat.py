"""Version-portability layer for JAX APIs that moved between releases.

The repo targets a range of JAX versions; the distributed layer is built on
``shard_map``, whose home and signature have churned:

  * <= 0.4.x : ``jax.experimental.shard_map.shard_map`` with ``check_rep``
  * >= 0.5   : ``jax.shard_map`` with ``check_rep`` renamed ``check_vma``

Every call site in the repo imports ``shard_map`` from here and may pass
either ``check_vma`` or ``check_rep``; the shim resolves the implementation
once at import and rewrites the kwarg to whatever the installed JAX accepts.
"""
from __future__ import annotations

import inspect

import jax

__all__ = ["shard_map", "SHARD_MAP_IMPL", "SHARD_MAP_CHECK_KWARG"]


def _resolve():
    """Find the installed shard_map and the name of its replication-check
    kwarg.  Returns (impl, kwarg_name | None)."""
    impl = getattr(jax, "shard_map", None)
    if impl is None:
        from jax.experimental.shard_map import shard_map as impl  # noqa: F811
    try:
        params = inspect.signature(impl).parameters
    except (TypeError, ValueError):   # C-implemented / wrapped callable
        params = {}
    for name in ("check_vma", "check_rep"):
        if name in params:
            return impl, name
    return impl, None


SHARD_MAP_IMPL, SHARD_MAP_CHECK_KWARG = _resolve()


def shard_map(f=None, /, *, mesh, in_specs, out_specs,
              check_vma=None, check_rep=None, **kwargs):
    """Portable ``shard_map``.

    Accepts the new-style ``check_vma`` or the old-style ``check_rep``
    spelling (they mean the same thing: verify that outputs declared
    replicated really are); whichever is given is forwarded under the name
    the installed JAX understands.  With ``f=None`` returns a decorator,
    matching the jax>=0.5 partial-application form.
    """
    if f is None:
        return lambda fn: shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, check_rep=check_rep, **kwargs)
    if check_vma is not None and check_rep is not None:
        raise ValueError("pass only one of check_vma/check_rep")
    check = check_vma if check_vma is not None else check_rep
    if check is not None:
        if SHARD_MAP_CHECK_KWARG is not None:
            kwargs[SHARD_MAP_CHECK_KWARG] = bool(check)
        else:
            # introspection failed (wrapped/C-implemented impl): probe both
            # spellings rather than silently dropping the flag — out_specs
            # in the distributed layer rely on the check being disabled.
            for name in ("check_vma", "check_rep"):
                try:
                    return SHARD_MAP_IMPL(
                        f, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, **{name: bool(check)}, **kwargs)
                except TypeError:
                    continue
    return SHARD_MAP_IMPL(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kwargs)
