"""Recsys retrieval with the paper's index (DESIGN.md §5): a SASRec user
tower scores 1M candidates — exact dot-product top-k vs the two-level
ANN index over the item embeddings.

  PYTHONPATH=src python examples/retrieval_recsys.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.core.metrics import recall_at_k
from repro.core.two_level import TwoLevelConfig, build_two_level
from repro.data.recsys import sasrec_batch
from repro.distributed.sharding import ShardPlan
from repro.models import recsys as R
from repro.models.recsys import _sasrec_hidden  # example-only internal use

cfg, _ = get_arch("sasrec")
cfg = dataclasses.replace(cfg.reduced(), n_items=100_000, embed_dim=32)
params = R.init(cfg, jax.random.PRNGKey(0))
print(f"SASRec items={cfg.n_items} d={cfg.embed_dim}")

batch = sasrec_batch(cfg, 8, step=0)
user = np.asarray(
    _sasrec_hidden(params, batch["seq"], cfg, ShardPlan())[:, -1]
)                                                   # (8, d) user vectors
items = np.asarray(params["item_table"])[: cfg.n_items]

# MIPS -> L2 reduction (Bachrach et al.): augment items with
# sqrt(M^2 - ||v||^2) and queries with 0; then
# ||q~ - v~||^2 = ||u||^2 + M^2 - 2 u.v, so L2-NN == max inner product.
norms2 = (items * items).sum(1, keepdims=True)
m2 = norms2.max()
aug_items = np.concatenate(
    [items, np.sqrt(np.maximum(m2 - norms2, 0.0))], axis=1
).astype(np.float32)
aug_user = np.concatenate(
    [user, np.zeros((user.shape[0], 1))], axis=1
).astype(np.float32)

t0 = time.time()
exact_scores = user @ items.T
exact_top = np.argsort(-exact_scores, axis=1)[:, :10]
t_exact = time.time() - t0

# MIPS over IVF needs wider probing than plain L2 (inner-product mass
# spreads across buckets when item norms are near-uniform)
cfgi = TwoLevelConfig(n_clusters=512, top="brute", bottom="brute",
                      kmeans_iters=8, kmeans_minibatch=50_000)
t0 = time.time()
index = build_two_level(aug_items, cfgi)
t_build = time.time() - t0

t0 = time.time()
_, ann_top, work = index.search(aug_user, 10, nprobe=64)
t_ann = time.time() - t0

r = recall_at_k(ann_top, exact_top)
print(f"exact scoring: {t_exact * 1e3:.0f} ms for 8 users")
print(f"two-level ANN: build {t_build:.1f}s, query {t_ann * 1e3:.0f} ms, "
      f"recall@10 vs exact = {r:.3f}, "
      f"candidates/query = {work['candidates'] / 8:.0f} "
      f"(vs {cfg.n_items} exact)")
