"""Quickstart: the paper's protocol end-to-end in ~30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.brute import brute_search
from repro.core.index import auto_build_index
from repro.core.likelihood import sample_queries, simulate_beta_likelihood
from repro.core.metrics import recall_at_k

rng = np.random.default_rng(0)

# 1. an entity catalog (10K radio-station-like embeddings)
centers = rng.normal(size=(64, 128)).astype(np.float32) * 4
db = (centers[rng.integers(0, 64, 10_000)]
      + rng.normal(size=(10_000, 128))).astype(np.float32)

# 2. skewed query traffic (paper §4.2)
p = simulate_beta_likelihood(rng, 10_000, 0.1, 8.0)

# 3. §5.3 protocol: <30K entities + traffic known -> QLBT
index = auto_build_index(db, p=p)
print(f"protocol chose: {index.spec.kind} — {index.spec.reason}")

# 4. search
queries, truth = sample_queries(rng, db, p, 256, noise_scale=0.05)
dists, ids, work = index.search(queries, k=10, beam_width=16)
print(f"recall@10 = {recall_at_k(ids, truth):.3f}")
print(f"mean work/query = "
      f"{(work['internal_visits'] + work['candidates']) / 256:.0f} "
      f"distance evals (vs {db.shape[0]} brute-force)")

# 5. sanity: exact search agrees
_, exact = brute_search(queries, db, 10)
print(f"recall@10 vs exact-NN = {recall_at_k(ids, exact):.3f}")
