"""Train a small LM end-to-end with the full substrate: AdamW, grad clip,
checkpointing, watchdog, deterministic restart.  (~25M params by default;
use --layers/--d-model to scale toward 100M if you have the minutes.)

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import os
import tempfile

import jax

from repro.configs.base import LMConfig
from repro.data.lm import LMStream
from repro.models import transformer as T
from repro.train import optim
from repro.train.fault import Watchdog
from repro.train.loop import init_state, make_train_step, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = LMConfig(
        name="tiny", n_layers=args.layers, d_model=args.d_model,
        n_heads=max(4, args.d_model // 64), n_kv_heads=2,
        d_head=64, d_ff=4 * args.d_model, vocab=8192, qk_norm=True,
        remat=False,
    )
    params = T.init(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n / 1e6:.1f}M params")

    opt = optim.adamw(optim.warmup_cosine(3e-4, 20, args.steps))
    state = init_state(params, opt)
    step = make_train_step(lambda p, b: T.loss_fn(p, b, cfg), opt)
    stream = LMStream(cfg.vocab, args.seq, args.batch, seed=0)
    ckpt = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                         "repro_lm_ckpt")
    wd = Watchdog()
    res = train(state, step, stream.batch_at, args.steps, log_every=20,
                ckpt_dir=ckpt, ckpt_every=100, watchdog=wd)
    for h in res.history:
        print(f"step {h['step']:4d}  loss {h['loss']:.3f}  "
              f"acc {h['accuracy']:.3f}  gnorm {h['grad_norm']:.2f}")
    import numpy as np

    print(f"mean step time: {np.mean(res.step_times[5:]) * 1e3:.0f} ms; "
          f"stragglers flagged: {len(wd.events)}; checkpoints in {ckpt}")


if __name__ == "__main__":
    main()
