"""End-to-end serving driver (the paper-kind dictates serving): build the
two-level index over a large catalog and serve batched requests through the
micro-batching engine with latency SLO tracking and a hedged replica.

  PYTHONPATH=src python examples/edge_serving.py [--n 200000] [--qps 500]
"""
import argparse
import threading
import time

import numpy as np

from repro.core.brute import brute_search
from repro.core.index import auto_build_index
from repro.core.metrics import recall_at_k
from repro.data.synthetic import make_corpus, make_queries
from repro.serve.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--n-requests", type=int, default=512)
    ap.add_argument("--qps", type=float, default=500.0)
    args = ap.parse_args()

    print(f"building corpus ({args.n} x 128)...")
    db = np.asarray(make_corpus("sift", scale=args.n / 1_000_000, seed=0))
    t0 = time.time()
    index = auto_build_index(db)           # §5.3 -> two-level PQ+brute
    print(f"index: {index.spec.kind} ({index.spec.reason}) "
          f"built in {time.time() - t0:.1f}s, "
          f"footprint {index.footprint_bytes(include_db=False) / 2**20:.1f}"
          f" MiB (+vectors)")

    def search_fn(qs):
        d, i, _ = index.search(qs, 10, nprobe=16)
        return d, i

    # replica for hedged requests (same index here; a second host in prod)
    engine = ServingEngine(search_fn, max_batch=64, max_wait_ms=3.0,
                           hedge_fn=search_fn, hedge_ms=250.0)

    queries = make_queries(db, args.n_requests, seed=1)
    print(f"replaying {args.n_requests} requests at ~{args.qps} qps...")
    futs = []

    def submit_all():
        for j in range(args.n_requests):
            futs.append(engine.submit(queries[j]))
            time.sleep(1.0 / args.qps)

    t = threading.Thread(target=submit_all)
    t.start()
    t.join()
    outs = [f.get(timeout=120) for f in futs]
    stats = engine.stats()
    engine.close()

    ids = np.stack([o[1] for o in outs])
    _, gt = brute_search(queries, db, 10)
    print(f"recall@10 = {recall_at_k(ids, gt):.3f}")
    print(f"latency: p50={stats.p50_ms:.1f}ms p90={stats.p90_ms:.1f}ms "
          f"p99={stats.p99_ms:.1f}ms (queue {stats.queue_ms:.1f}ms), "
          f"hedges={stats.hedges}")
    print(f"batch sizes (last): {stats.batch_sizes[-8:]}")
    ok = stats.p90_ms < 80.0
    print(f"paper SLO (P90 < 80 ms): {'MET' if ok else 'MISSED'}")


if __name__ == "__main__":
    main()
